"""LM data pipeline: determinism, resume, shard migration, subset selection."""
import jax
import numpy as np
import pytest

from repro.core.gen_dst import GenDSTConfig
from repro.data.pipeline import (
    ShardedLoader, SyntheticCorpus, corpus_to_coded, select_corpus_subset,
)


@pytest.fixture(scope="module")
def corpus():
    return SyntheticCorpus(n_seqs=512, seq_len=64, vocab=1000, seed=0)


def test_corpus_deterministic(corpus):
    a = corpus.rows(np.array([3, 7, 11]))
    b = corpus.rows(np.array([3, 7, 11]))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 64)
    assert (a >= 0).all() and (a < 1000).all()


def test_loader_deterministic_and_resumable(corpus):
    l1 = ShardedLoader(corpus, global_batch=16, seed=1)
    b0, b1 = l1.next(), l1.next()
    l2 = ShardedLoader(corpus, global_batch=16, seed=1)
    np.testing.assert_array_equal(l2.next()["tokens"], b0["tokens"])
    st = l2.state()
    np.testing.assert_array_equal(l2.next()["tokens"], b1["tokens"])
    l2.restore(st)
    np.testing.assert_array_equal(l2.next()["tokens"], b1["tokens"])


def test_loader_labels_shifted(corpus):
    b = ShardedLoader(corpus, global_batch=4, seed=2).next()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_loader_shards_disjoint(corpus):
    """Two hosts of the same loader see disjoint slices that union to the
    global batch."""
    mk = lambda h: ShardedLoader(corpus, global_batch=16, n_hosts=4, host_id=h, seed=3)
    batches = [mk(h).next() for h in range(4)]
    total = sum(b["tokens"].shape[0] for b in batches)
    assert total == 16
    stacked = np.concatenate([b["tokens"] for b in batches])
    assert stacked.shape == (16, 63)


def test_loader_dead_host_shards_migrate(corpus):
    """With host 1 dead, its slice shows up on the survivors."""
    alive = [0, 2, 3]
    batches = [
        ShardedLoader(corpus, global_batch=16, n_hosts=4, host_id=h, seed=3).next(alive)
        for h in alive
    ]
    total = sum(b["tokens"].shape[0] for b in batches)
    assert total == 16, "dead host's shard must migrate to survivors"


def test_corpus_to_coded(corpus):
    coded, row_ids = corpus_to_coded(corpus, n_position_buckets=16, sample_rows=128)
    assert coded.codes.shape == (128, 16)
    assert len(row_ids) == 128
    assert int(coded.codes.max()) < coded.max_bins


def test_select_corpus_subset(corpus):
    ids = select_corpus_subset(
        corpus, 32, key=jax.random.key(0),
        cfg=GenDSTConfig(psi=3, phi=8), n_position_buckets=16, sample_rows=128,
    )
    assert len(ids) == 32
    assert (ids >= 0).all() and (ids < len(corpus)).all()
    # loader accepts the subset
    loader = ShardedLoader(corpus, global_batch=8, seed=0, subset=ids)
    b = loader.next()
    assert b["tokens"].shape == (8, 63)
