"""JAX-native AutoML engine: learns, restricts, budgets."""
import numpy as np
import pytest

from repro.automl.engine import AutoMLConfig, automl_fit
from repro.automl.models import FAMILIES, accuracy, train_model, predict_model
import jax
import jax.numpy as jnp


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    N = 600
    y = rng.integers(0, 2, N)
    X = np.column_stack([
        y * 2.0 + rng.normal(0, 0.5, N),
        -y * 1.5 + rng.normal(0, 0.5, N),
        rng.normal(0, 1, N),
    ]).astype(np.float32)
    return X[:500], y[:500], X[500:], y[500:]


def test_each_family_trains(data):
    X, y, Xt, yt = data
    for fam in FAMILIES:
        hp = {k: v[0] for k, v in FAMILIES[fam].hp_grid.items()}
        params = train_model(jax.random.key(0), jnp.asarray(X), jnp.asarray(y),
                             fam, 2, hp, epochs=40)
        acc = accuracy(params, jnp.asarray(Xt), jnp.asarray(yt), fam)
        assert acc > 0.7, f"{fam} acc {acc}"


def test_automl_finds_good_pipeline(data):
    X, y, Xt, yt = data
    res = automl_fit(X, y, config=AutoMLConfig(n_trials=8, rungs=(20, 60)),
                     X_test=Xt, y_test=yt)
    assert res.val_acc > 0.85
    assert res.test_acc > 0.85
    assert res.n_trials >= 8
    assert res.time_s > 0


def test_automl_restrict_family(data):
    X, y, _, _ = data
    res = automl_fit(X, y, config=AutoMLConfig(n_trials=6, rungs=(20,)),
                     restrict_family="logreg")
    assert res.spec.family == "logreg"
    assert all(s.family == "logreg" for s, _ in res.trials)


def test_automl_time_budget(data):
    X, y, _, _ = data
    res = automl_fit(X, y, config=AutoMLConfig(
        n_trials=64, rungs=(20, 60, 120), time_budget_s=3.0))
    # budget cuts the search well short of 64 * 3 rungs
    assert res.n_trials < 150
    assert res.val_acc > 0.5


def test_automl_multiclass():
    rng = np.random.default_rng(1)
    N = 400
    y = rng.integers(0, 3, N)
    X = np.column_stack([(y == k) * 2.0 + rng.normal(0, 0.4, N) for k in range(3)])
    res = automl_fit(X.astype(np.float32), y,
                     config=AutoMLConfig(n_trials=6, rungs=(30,)))
    assert res.val_acc > 0.8
