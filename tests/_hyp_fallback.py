"""Tiny stand-in for the parts of ``hypothesis`` this suite uses.

When the real ``hypothesis`` package is installed it is always preferred
(test modules try it first); this fallback only exists so the tier-1 suite
collects and passes in minimal environments.  It implements deterministic
pseudo-random example generation for ``@given`` over ``st.integers`` /
``st.floats`` — no shrinking, no database, no deadlines.
"""
from __future__ import annotations

import random

__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, min_value, max_value, draw):
        self.min_value = min_value
        self.max_value = max_value
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(min_value, max_value,
                         lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(min_value, max_value,
                         lambda rng: rng.uniform(min_value, max_value))


def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
    """Decorator: records ``max_examples`` on a ``@given``-wrapped test."""
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    """Decorator: runs the test once per generated example.

    The two boundary tuples (all-min, all-max) always run first; the rest
    are drawn from an RNG seeded by the test name, so failures reproduce.
    """
    def deco(fn):
        # NB: no functools.wraps — pytest must see the wrapper's zero-arg
        # signature, not fn's strategy parameters (it would hunt fixtures).
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(fn.__name__)
            examples = [
                tuple(s.min_value for s in strats),
                tuple(s.max_value for s in strats),
            ]
            while len(examples) < n:
                examples.append(tuple(s.example(rng) for s in strats))
            for ex in examples[:n]:
                fn(*args, *ex, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
