"""Fused Gen-DST generation kernel (DESIGN.md §16): parity + padding edges.

Three-way parity contract: the interpret-mode Pallas kernel must match the
pure-jnp oracle bit-for-bit on CPU (identical op sequence on exact
integer-valued f32 counts); the compiled (Mosaic) leg runs only on a real
TPU backend.  End-to-end, ``backend="pallas_fused"`` must reproduce the
``backend="jnp"`` GA trajectory exactly for the same seed — winner rows,
winner column mask, and fitness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gen_dst import GenDSTConfig, gen_dst, gen_dst_batch
from repro.core.measures import factorize
from repro.kernels.gen_dst.kernel import fused_delta_fitness_pallas
from repro.kernels.gen_dst.ops import fused_delta_fitness
from repro.kernels.gen_dst.ref import fused_delta_fitness_ref

ON_TPU = jax.default_backend() == "tpu"


def _case(P, M, B, seed, code_max=None):
    """Random fused-kernel inputs; ``code_max`` < B leaves padding bins."""
    rng = np.random.default_rng(seed)
    hi = B if code_max is None else code_max
    n = 12  # rows per candidate histogram
    base = rng.integers(0, hi, (P, n, M))
    counts = np.zeros((P, M, B), np.float32)
    for p in range(P):
        for j in range(M):
            np.add.at(counts[p, j], base[p, :, j], 1.0)
    old = base[:, 0, :].astype(np.int32)           # evict a real member row
    new = rng.integers(0, hi, (P, M)).astype(np.int32)
    applied = rng.random(P) < 0.6
    col_mask = rng.random((P, M)) < 0.5
    col_mask[:, 0] = True                           # never an empty mask
    f_ref = np.float32(rng.random() * 3.0)
    return (jnp.asarray(counts), jnp.asarray(old), jnp.asarray(new),
            jnp.asarray(applied), jnp.asarray(col_mask), jnp.asarray(f_ref))


# --- kernel-level parity, incl. the padding edges ---------------------------

FUSED_CASES = [
    # (P, M, B, code_max): P < tile_p, P % tile_p != 0, B > max code
    (3, 4, 8, None),       # P=3 < tile_p=8 — single padded candidate tile
    (10, 5, 16, None),     # P=10 % 8 != 0 — ragged last tile
    (16, 3, 32, 17),       # codes < 17 < B=32 — padding bins must stay empty
    (8, 7, 8, None),       # exact tile fit
    (25, 2, 64, 40),       # ragged + padding bins together
]


@pytest.mark.parametrize("P,M,B,code_max", FUSED_CASES)
def test_fused_kernel_matches_ref(P, M, B, code_max):
    args = _case(P, M, B, seed=P * 131 + B, code_max=code_max)
    c_ref, f_ref_out = fused_delta_fitness_ref(*args)
    c_k, f_k = fused_delta_fitness_pallas(*args, bins=B, interpret=True)
    # bit-level oracle: identical op sequence on exact small-integer counts
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref_out))


@pytest.mark.skipif(not ON_TPU, reason="compiled Mosaic leg needs a TPU")
@pytest.mark.parametrize("P,M,B,code_max", FUSED_CASES)
def test_fused_kernel_compiled_matches_ref(P, M, B, code_max):
    args = _case(P, M, B, seed=P * 131 + B, code_max=code_max)
    c_ref, f_ref_out = fused_delta_fitness_ref(*args)
    c_k, f_k = fused_delta_fitness_pallas(*args, bins=B, interpret=False)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_ref_out), atol=1e-5)


def test_fused_kernel_mass_conservation_and_padding_bins():
    """A row swap conserves per-column mass; codes < B leaves the high
    padding bins untouched (all-zero before and after the delta)."""
    P, M, B, code_max = 10, 4, 32, 9
    counts, old, new, applied, cm, fr = _case(P, M, B, seed=5, code_max=code_max)
    c_k, _ = fused_delta_fitness_pallas(
        counts, old, new, applied, cm, fr, bins=B, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(c_k.sum(-1)), np.asarray(counts.sum(-1)))
    assert not np.asarray(c_k)[:, :, code_max:].any()


def test_fused_op_leading_axes_roundtrip():
    """ops.fused_delta_fitness flattens (islands, phi, ...) leading axes and
    restores them; result matches candidate-by-candidate ref calls."""
    counts, old, new, applied, cm, fr = _case(12, 3, 8, seed=9)
    sh = lambda a, tail: a.reshape(2, 6, *tail)
    c2, fit = fused_delta_fitness(
        sh(counts, (3, 8)), sh(old, (3,)), sh(new, (3,)), applied.reshape(2, 6),
        sh(cm, (3,)), fr, backend="pallas_fused", interpret=True)
    assert c2.shape == (2, 6, 3, 8) and fit.shape == (2, 6)
    c_ref, f_ref_out = fused_delta_fitness_ref(counts, old, new, applied, cm, fr)
    np.testing.assert_array_equal(np.asarray(c2).reshape(12, 3, 8), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(fit).reshape(12), np.asarray(f_ref_out))


def test_fused_op_unknown_backend_raises():
    args = _case(4, 2, 4, seed=0)
    with pytest.raises(ValueError, match="unknown fused Gen-DST backend"):
        fused_delta_fitness(*args, backend="cuda")


# --- end-to-end GA parity: backend="pallas_fused" vs "jnp" ------------------


@pytest.fixture(scope="module")
def coded():
    rng = np.random.default_rng(42)
    X = np.column_stack([
        rng.integers(0, k, 400) for k in (3, 5, 11, 2, 20)
    ]).astype(float)
    y = rng.integers(0, 2, 400).astype(float)
    return factorize(X, y)


@pytest.mark.parametrize("cross_every,num_islands", [(1, 1), (3, 2)])
def test_fused_backend_same_winner_as_jnp(coded, cross_every, num_islands):
    mk = lambda b: GenDSTConfig(psi=4, phi=8, backend=b,
                                cross_every=cross_every,
                                num_islands=num_islands, migrate_every=2)
    key = jax.random.key(17)
    r_j = gen_dst(key, coded, 16, 3, mk("jnp"))
    r_f = gen_dst(key, coded, 16, 3, mk("pallas_fused"))
    np.testing.assert_array_equal(np.asarray(r_f.row_idx), np.asarray(r_j.row_idx))
    np.testing.assert_array_equal(np.asarray(r_f.col_mask), np.asarray(r_j.col_mask))
    assert abs(float(r_f.fitness) - float(r_j.fitness)) < 1e-5
    np.testing.assert_allclose(np.asarray(r_f.history), np.asarray(r_j.history),
                               atol=1e-5)


def test_fused_backend_batch_matches_solo_jnp(coded):
    cfg_f = GenDSTConfig(psi=4, phi=8, backend="pallas_fused", cross_every=2)
    cfg_j = cfg_f._replace(backend="jnp")
    keys = [jax.random.key(3), jax.random.key(4)]
    batch = gen_dst_batch(keys, [coded, coded], 16, 3, cfg_f)
    for k, res in zip(keys, batch):
        solo = gen_dst(k, coded, 16, 3, cfg_j)
        np.testing.assert_array_equal(np.asarray(res.row_idx),
                                      np.asarray(solo.row_idx))
        assert abs(float(res.fitness) - float(solo.fitness)) < 1e-5
