"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps +
hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env — deterministic fallback, same API subset
    from _hyp_fallback import given, settings, strategies as st

from repro.kernels.entropy.kernel import masked_histogram_pallas
from repro.kernels.entropy.ref import masked_histogram_ref, entropy_from_hist
from repro.kernels.entropy.ops import column_entropy_masked
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------------------
# entropy / masked histogram
# ---------------------------------------------------------------------------

ENTROPY_SHAPES = [
    (16, 1, 2), (100, 5, 7), (1000, 23, 256), (513, 3, 16),
    (2048, 8, 64), (77, 123, 11),
]


@pytest.mark.parametrize("N,M,B", ENTROPY_SHAPES)
def test_entropy_kernel_matches_ref(N, M, B):
    rng = np.random.default_rng(N * 31 + M)
    codes = jnp.asarray(rng.integers(0, B, (N, M)), jnp.int32)
    w = jnp.asarray((rng.random(N) < 0.4).astype(np.float32))
    h_k = masked_histogram_pallas(codes, w, B)
    h_r = masked_histogram_ref(codes, w, B)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-4)


@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_entropy_kernel_weight_dtypes(wdtype):
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 16, (256, 4)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 2, 256), wdtype)
    h_k = masked_histogram_pallas(codes, w.astype(jnp.float32), 16)
    h_r = masked_histogram_ref(codes, w.astype(jnp.float32), 16)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-4)


@pytest.mark.parametrize("tile_n,tile_m", [(64, 2), (128, 8), (1024, 8)])
def test_entropy_kernel_tile_sweep(tile_n, tile_m):
    rng = np.random.default_rng(7)
    codes = jnp.asarray(rng.integers(0, 32, (500, 9)), jnp.int32)
    w = jnp.asarray(rng.random(500), jnp.float32)
    h_k = masked_histogram_pallas(codes, w, 32, tile_n=tile_n, tile_m=tile_m)
    h_r = masked_histogram_ref(codes, w, 32)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 200), st.integers(1, 6), st.integers(2, 32), st.integers(0, 99))
def test_entropy_kernel_property(N, M, B, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, B, (N, M)), jnp.int32)
    w = jnp.asarray(rng.random(N), jnp.float32)
    h_k = masked_histogram_pallas(codes, w, B)
    # mass conservation: every column's histogram sums to sum(w)
    np.testing.assert_allclose(np.asarray(h_k.sum(axis=1)),
                               float(w.sum()) * np.ones(M), rtol=1e-4)
    h_r = masked_histogram_ref(codes, w, B)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-3)


# padding edges (DESIGN.md §16.4): rows shorter than one tile, a ragged
# column tile, and bins beyond every observed code (B > max(n_bins)) must
# all agree compiled/interpret/jnp — padding lanes carry zero weight.
PADDING_EDGE_SHAPES = [
    # (N, M, B, code_max)
    (5, 3, 8, None),        # N=5 < tile_n — one mostly-padded row tile
    (300, 13, 16, None),    # M=13 % tile_m=8 != 0 — ragged column tile
    (200, 4, 64, 11),       # codes < 11 << B=64 — padding bins
    (7, 9, 32, 5),          # all three edges at once
]


def _padding_case(N, M, B, code_max):
    rng = np.random.default_rng(N * 7 + M)
    hi = B if code_max is None else code_max
    codes = jnp.asarray(rng.integers(0, hi, (N, M)), jnp.int32)
    w = jnp.asarray(rng.random(N), jnp.float32)
    return codes, w


@pytest.mark.parametrize("N,M,B,code_max", PADDING_EDGE_SHAPES)
def test_histogram_padding_edges_interpret(N, M, B, code_max):
    codes, w = _padding_case(N, M, B, code_max)
    h_k = masked_histogram_pallas(codes, w, B, interpret=True)
    h_r = masked_histogram_ref(codes, w, B)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-4)
    if code_max is not None:  # bins no code can reach must stay empty
        assert not np.asarray(h_k)[:, code_max:].any()


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Mosaic leg needs a TPU")
@pytest.mark.parametrize("N,M,B,code_max", PADDING_EDGE_SHAPES)
def test_histogram_padding_edges_compiled(N, M, B, code_max):
    codes, w = _padding_case(N, M, B, code_max)
    h_k = masked_histogram_pallas(codes, w, B, interpret=False)
    h_r = masked_histogram_ref(codes, w, B)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-4)


def test_column_entropy_masked_matches_measures():
    from repro.core.measures import column_entropy
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(0, 8, (300, 5)), jnp.int32)
    mask = jnp.asarray((rng.random(300) < 0.5).astype(np.float32))
    h1 = column_entropy_masked(codes, mask, 8, use_pallas=True)
    h2 = column_entropy(codes, 8, weights=mask)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, Sq, Skv, H, K, hd, causal, dtype)
    (2, 128, 128, 4, 2, 64, True, jnp.float32),
    (1, 256, 256, 8, 8, 32, True, jnp.float32),
    (2, 128, 128, 4, 1, 128, False, jnp.float32),
    (1, 128, 128, 4, 4, 256, True, jnp.float32),
    (2, 128, 128, 8, 2, 64, True, jnp.bfloat16),
]


@pytest.mark.parametrize("B,Sq,Skv,H,K,hd,causal,dtype", FA_CASES)
def test_flash_attention_matches_ref(B, Sq, Skv, H, K, hd, causal, dtype):
    rng = np.random.default_rng(Sq + H)
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, H, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, Skv, K, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, Skv, K, hd)), dtype)
    o_k = flash_attention_pallas(q, k, v, causal=causal, block_q=64, block_k=64)
    o_r = attention_ref(q, k, v, causal=causal)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o_k, np.float32), np.asarray(o_r, np.float32), atol=atol
    )


@pytest.mark.parametrize("block_q,block_k", [(32, 64), (64, 32), (128, 128)])
def test_flash_attention_block_sweep(block_q, block_k):
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(0, 1, (1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 128, 2, 32)), jnp.float32)
    o_k = flash_attention_pallas(q, k, v, causal=True,
                                 block_q=block_q, block_k=block_k)
    o_r = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


def test_flash_attention_softmax_rows_normalized():
    """Causal row 0 attends only to key 0 => output == v[0]."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 64, 2, 16)), jnp.float32)
    o = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(o[0, 0]), np.asarray(v[0, 0]), atol=1e-5)
