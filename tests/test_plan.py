"""Plan-based pipeline API (DESIGN.md §12): SubsetStrategy / SearchBackend
registries, plan()/execute() parity with the legacy entry points, the
deprecation shims, and baselines-as-plans.

The headline assertions are the PR's acceptance criteria: every baseline
strategy runs through plan()/execute() with parity against its direct
invocation, deprecation shims emit DeprecationWarning and produce identical
results to the new API (winner spec equal, accs within 1e-6), and unknown
registry names raise errors listing what exists."""
import dataclasses

import numpy as np
import pytest

import jax

from repro.automl.engine import (
    AutoMLConfig, automl_fit, available_backends, get_backend,
    register_backend, BACKENDS, _eval_rung_loop,
)
from repro.core.gen_dst import DSTResult, GenDSTConfig, gen_dst
from repro.core.measures import factorize
from repro.core.plan import Plan, execute, plan, plan_from_config
from repro.core.strategies import (
    STRATEGIES, SubsetResult, available_strategies, get_strategy,
    register_strategy, run_strategy,
)
from repro.core.substrat import (
    SubStratConfig, build_subset, dst_feature_columns, substrat,
)

SMALL_AUTOML = AutoMLConfig(n_trials=5, rungs=(15, 40))
SMALL_FT = AutoMLConfig(n_trials=4, rungs=(40,))
SMALL_GEN = GenDSTConfig(psi=4, phi=8)
SMALL_CFG = SubStratConfig(gen=SMALL_GEN, sub_automl=SMALL_AUTOML,
                           ft_automl=SMALL_FT)


@pytest.fixture(scope="module")
def data():
    r = np.random.default_rng(0)
    y = r.integers(0, 2, 600)
    X = np.column_stack(
        [y * 1.5 + r.normal(0, 0.8, 600) for _ in range(6)]).astype(np.float32)
    return X[:480], y[:480], X[480:], y[480:]


# ---------------------------------------------------------------------------
# registries: unknown names, listings, third-party round-trips
# ---------------------------------------------------------------------------


def test_unknown_strategy_lists_available():
    with pytest.raises(ValueError, match="available strategies"):
        get_strategy("definitely_not_registered")
    with pytest.raises(ValueError, match="gen_dst"):
        get_strategy("nope")            # the listing names what exists


def test_unknown_backend_lists_available():
    with pytest.raises(ValueError, match="available backends"):
        get_backend("definitely_not_registered")
    with pytest.raises(ValueError, match="batched"):
        get_backend("nope")


def test_plan_validates_names_eagerly():
    with pytest.raises(ValueError, match="available strategies"):
        plan("no_such_strategy")
    with pytest.raises(ValueError, match="available backends"):
        plan("gen_dst", backend="no_such_backend")


def test_builtin_registrations_cover_baselines():
    names = available_strategies()
    for expected in ("gen_dst", "gen_dst_islands", "mc", "mab", "greedy_seq",
                     "greedy_mult", "km", "ig_rand", "ig_km", "asp_proxy",
                     "random"):
        assert expected in names
    assert set(("batched", "loop")) <= set(available_backends())


def test_third_party_strategy_roundtrip(data):
    X, y, *_ = data

    def fixed_dst(key, coded, n, m, *, rows=10):
        M = coded.num_cols
        mask = np.zeros(M, bool)
        mask[[0, 1, M - 1]] = True
        import jax.numpy as jnp
        return DSTResult(jnp.arange(rows, dtype=jnp.int32), jnp.asarray(mask),
                         jnp.float32(-0.5), jnp.zeros((0,)), jnp.float32(0.0))

    try:
        register_strategy("fixed_test_dst", fixed_dst)
        assert "fixed_test_dst" in available_strategies()
        with pytest.raises(ValueError, match="already registered"):
            register_strategy("fixed_test_dst", fixed_dst)
        res = execute(plan("fixed_test_dst", rows=12, sub_automl=SMALL_AUTOML,
                           ft_automl=SMALL_FT), X, y, key=jax.random.key(0))
        np.testing.assert_array_equal(res.row_idx, np.arange(12))
        assert res.strategy == "fixed_test_dst"
    finally:
        STRATEGIES.pop("fixed_test_dst", None)


def test_third_party_backend_roundtrip(data):
    X, y, *_ = data
    calls = []

    def traced_loop(cohort, tids, rung_i, epochs, ctx, out_of_budget,
                    collect_params=True):
        calls.append(len(cohort))
        return _eval_rung_loop(cohort, tids, rung_i, epochs, ctx,
                               out_of_budget, collect_params)

    try:
        register_backend("traced_loop", traced_loop)
        ref = automl_fit(X, y, config=dataclasses.replace(
            SMALL_AUTOML, backend="loop"))
        res = automl_fit(X, y, config=dataclasses.replace(
            SMALL_AUTOML, backend="traced_loop"))
        assert calls, "registered backend was never invoked"
        assert res.spec == ref.spec
        assert res.val_acc == pytest.approx(ref.val_acc, abs=1e-6)
    finally:
        BACKENDS.pop("traced_loop", None)


# ---------------------------------------------------------------------------
# plan()/execute() vs the legacy entry points (deprecation shims)
# ---------------------------------------------------------------------------


def test_plan_from_config_execute_matches_substrat(data):
    X, y, Xte, yte = data
    old = substrat(X, y, key=jax.random.key(3), config=SMALL_CFG,
                   X_test=Xte, y_test=yte)
    new = execute(plan_from_config(SMALL_CFG), X, y, key=jax.random.key(3),
                  X_test=Xte, y_test=yte)
    assert new.final.spec == old.final.spec
    assert new.final.val_acc == pytest.approx(old.final.val_acc, abs=1e-6)
    assert new.final.test_acc == pytest.approx(old.final.test_acc, abs=1e-6)
    np.testing.assert_array_equal(new.row_idx, old.row_idx)
    np.testing.assert_array_equal(new.col_idx, old.col_idx)


def test_dst_fn_shim_warns_and_matches_plan(data):
    """The deprecated dst_fn= signature still works, warns, and produces
    exactly the callable-strategy plan's result."""
    X, y, Xte, yte = data

    def my_dst(key, coded, n, m):
        M = coded.num_cols
        mask = np.zeros(M, bool)
        mask[[0, 2, M - 1]] = True
        import jax.numpy as jnp
        return DSTResult(jnp.arange(40, dtype=jnp.int32), jnp.asarray(mask),
                         jnp.float32(-0.25), jnp.zeros((0,)), jnp.float32(0.0))

    with pytest.deprecated_call():
        old = substrat(X, y, key=jax.random.key(1), config=SMALL_CFG,
                       dst_fn=my_dst, X_test=Xte, y_test=yte)
    new = execute(plan(my_dst, sub_automl=SMALL_AUTOML, ft_automl=SMALL_FT),
                  X, y, key=jax.random.key(1), X_test=Xte, y_test=yte)
    assert old.final.spec == new.final.spec
    assert old.final.val_acc == pytest.approx(new.final.val_acc, abs=1e-6)
    assert old.final.test_acc == pytest.approx(new.final.test_acc, abs=1e-6)
    np.testing.assert_array_equal(old.row_idx, new.row_idx)


def test_service_dst_fn_shim_warns(data):
    from repro.service import SubStratServer
    from repro.core.gen_dst import random_dst
    X, y, *_ = data
    srv = SubStratServer()
    with pytest.deprecated_call():
        srv.submit(X, y, config=SMALL_CFG, dst_fn=random_dst)


def test_plan_is_hashable_and_normalizes_opts():
    a = plan("mc", budget=60, batch=20)
    b = Plan(strategy="mc", strategy_opts=(("batch", 20), ("budget", 60)))
    assert a == b and hash(a) == hash(b)
    assert a.strategy_opts == (("batch", 20), ("budget", 60))


def test_plan_backend_override_applies_to_both_passes():
    p = plan("gen_dst", backend="loop", sub_automl=SMALL_AUTOML,
             ft_automl=SMALL_FT)
    assert p.resolved_sub_automl().backend == "loop"
    assert p.resolved_ft_automl().backend == "loop"


# ---------------------------------------------------------------------------
# every baseline through plan()/execute(), parity with direct invocation
# ---------------------------------------------------------------------------


BASELINE_PLANS = [
    ("mc", (("budget", 60), ("batch", 20))),
    ("mab", (("rounds", 30),)),
    ("greedy_seq", (("pool", 16),)),
    ("greedy_mult", (("pool", 16),)),
    ("km", ()),
    ("ig_rand", ()),
    ("ig_km", ()),
    ("asp_proxy", ()),
]


@pytest.mark.parametrize("name,opts", BASELINE_PLANS,
                         ids=[n for n, _ in BASELINE_PLANS])
def test_baseline_through_plan_matches_direct(name, opts, data):
    """Acceptance: each core/baselines.py method runs through the plan API
    and selects exactly the subset its direct invocation selects."""
    X, y, *_ = data
    key = jax.random.key(5)
    coded = factorize(X, y)

    direct = run_strategy(name, key, coded, 20, 3, opts)
    assert isinstance(direct, SubsetResult)

    res = execute(
        dataclasses.replace(plan(name, n=20, m=3, sub_automl=SMALL_AUTOML,
                                 ft_automl=SMALL_FT), strategy_opts=opts),
        X, y, key=key)
    np.testing.assert_array_equal(res.row_idx, direct.row_idx)
    assert res.dst_fitness == pytest.approx(direct.fitness, abs=1e-6)
    assert res.strategy == name
    # and the AutoML passes completed on that subset
    assert res.final.val_acc is not None
    np.testing.assert_array_equal(
        res.col_idx, dst_feature_columns(direct.col_mask, coded.target_col))


def test_gen_dst_plan_matches_direct(data):
    X, y, *_ = data
    key = jax.random.key(9)
    coded = factorize(X, y)
    direct = gen_dst(key, coded, 20, 3, SMALL_GEN)
    res = execute(plan("gen_dst", n=20, m=3, cfg=SMALL_GEN,
                       sub_automl=SMALL_AUTOML, ft_automl=SMALL_FT),
                  X, y, key=key)
    np.testing.assert_array_equal(res.row_idx, np.asarray(direct.row_idx))
    assert res.dst_fitness == pytest.approx(float(direct.fitness), abs=1e-6)


def test_asp_proxy_subset_is_valid(data):
    """The ASP-style proxy scorer produces a valid, class-covering subset."""
    X, y, *_ = data
    coded = factorize(X, y)
    res = run_strategy("asp_proxy", jax.random.key(0), coded, 24, 3)
    assert res.row_idx.shape == (24,)
    assert len(np.unique(res.row_idx)) == 24        # no duplicate rows
    assert res.col_mask[coded.target_col]
    assert 2 <= res.col_mask.sum() <= 3
    assert np.isfinite(res.fitness)
    # stratified selection keeps every class represented
    assert set(np.unique(y[res.row_idx])) == set(np.unique(y))
