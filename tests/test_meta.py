"""Cross-tenant meta-learning (DESIGN.md §17): the experience store, the
greedy submodular portfolio builder, and the warm-start path.

Layers under test, bottom up:

- ``meta.portfolio`` — property-based: greedy coverage is monotone
  non-decreasing in k; the selection is a pure function of the history
  *contents* (permuting insertion order changes nothing); a portfolio of
  k >= the number of distinct per-dataset winners recovers every winner.
- ``engine.search_init(seed_trials=...)`` — None/empty is byte-for-byte
  the cold path; a seeded subset keeps the sampled trial ids, so its
  rung-0 accuracies are bit-identical to the same trials of a cold run;
  novel specs append with fresh ids.
- ``meta.ExperienceStore`` — ``state_dict`` round-trips through the wire
  codec bytes-identically.
- the ``Scheduler`` — snapshots carry the store and the restored scheduler
  makes identical portfolio decisions; a warm-started job reaches the cold
  run's winner accuracy with strictly fewer dispatched trials;
  ``Plan(warm_start=False)`` restores the exact cold behavior.
- ``server.TokenBucket`` / rate limiting — deterministic under an
  injected clock; ``submit`` raises ``RateLimited``; the HTTP layer maps
  it to 429 + ``Retry-After``.

Property tests use ``hypothesis`` when installed and fall back to the
deterministic ``_hyp_fallback`` shim otherwise (CI runs both legs).
"""
import urllib.error
import urllib.request

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # minimal environments
    from _hyp_fallback import given, settings, strategies as st

from repro.automl.engine import (
    AutoMLConfig, PipelineSpec, search_eval_rung, search_init,
)
from repro.core.measures import factorize
from repro.core.plan import plan
from repro.meta import (
    ExperienceStore, META_FEATURE_NAMES, greedy_portfolio, knn_fingerprints,
    meta_features, portfolio_coverage, portfolio_for, spec_sort_key,
)
from repro.service import (
    RateLimited, SubStratServer, TokenBucket, wire,
)
from repro.service.scheduler import Scheduler


def _spec(i: int) -> PipelineSpec:
    return PipelineSpec(preproc="none", feature_frac=1.0,
                        family=f"fam{i}", hp=(("lr", i),))


def _matrix_from_rng(rng, n_specs: int, n_datasets: int):
    return {
        _spec(i): {f"fp{j}": float(rng.uniform(0.3, 1.0))
                   for j in range(n_datasets)}
        for i in range(n_specs)
    }


def _make_data(seed: int, N: int = 150, d: int = 6):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, N)
    X = np.column_stack([y * 1.5 + rng.normal(0, 0.8, N) for _ in range(d)])
    return X, y


# ---------------------------------------------------------------------------
# portfolio builder
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 9), st.integers(1, 5), st.integers(0, 10_000))
def test_greedy_coverage_monotone(n_specs, n_datasets, seed):
    matrix = _matrix_from_rng(np.random.default_rng(seed), n_specs,
                              n_datasets)
    last = 0.0
    for k in range(1, n_specs + 2):
        cov = portfolio_coverage(matrix, greedy_portfolio(matrix, k))
        assert cov >= last - 1e-12
        last = cov
    # full-portfolio coverage equals the matrix's ceiling
    ceiling = portfolio_coverage(matrix, list(matrix))
    assert last == pytest.approx(ceiling)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(2, 5), st.integers(0, 10_000))
def test_selection_invariant_under_insertion_order(n_specs, n_datasets, seed):
    rng = np.random.default_rng(seed)
    entries = []
    for i in range(n_specs):
        for j in range(n_datasets):
            entries.append((f"fp{j}", _spec(i), int(rng.integers(0, 3)),
                            float(rng.uniform(0.3, 1.0))))
    winners = {f"fp{j}": _spec(int(rng.integers(0, n_specs)))
               for j in range(n_datasets)}
    feats = {f"fp{j}": rng.normal(size=len(META_FEATURE_NAMES))
                       .astype(np.float32)
             for j in range(n_datasets)}

    def build(order):
        store = ExperienceStore()
        for idx in order:
            fp, spec, rung, acc = entries[idx]
            store.note_trial(fp, spec, rung, acc)
        for fp in sorted(winners):
            store.note_meta(fp, feats[fp])
            store.note_winner(fp, winners[fp])
        return store

    base = build(range(len(entries)))
    query = rng.normal(size=len(META_FEATURE_NAMES)).astype(np.float32)
    expected = portfolio_for(base, query, k=3, knn=2)
    for _ in range(3):
        perm = rng.permutation(len(entries))
        assert portfolio_for(build(perm), query, k=3, knn=2) == expected


def test_k_covers_every_distinct_winner():
    # spec i is the unique maximum on dataset i: any coverage-maximizing
    # portfolio of k >= n must contain every one of them
    n = 5
    matrix = {}
    for i in range(n):
        accs = {f"fp{j}": 0.5 for j in range(n)}
        accs[f"fp{i}"] = 0.9 + 0.01 * i
        matrix[_spec(i)] = accs
    chosen = greedy_portfolio(matrix, n)
    assert set(chosen) == set(matrix)
    # and the families they carry are all recovered
    assert {s.family for s in chosen} == {f"fam{i}" for i in range(n)}


def test_greedy_size_and_tie_break():
    matrix = {_spec(i): {"fp0": 0.7} for i in range(4)}   # 4-way exact tie
    assert greedy_portfolio(matrix, 2) == sorted(matrix,
                                                 key=spec_sort_key)[:2]
    assert len(greedy_portfolio(matrix, 99)) == len(matrix)
    assert greedy_portfolio({}, 3) == []


def test_knn_slice():
    feats = {
        "a": np.array([0.0, 0.0], np.float32),
        "b": np.array([1.0, 0.0], np.float32),
        "c": np.array([5.0, 0.0], np.float32),
    }
    q = np.array([0.4, 0.0], np.float32)
    assert knn_fingerprints(feats, q, 2) == ["a", "b"]
    # exact distance tie -> lexically smaller fingerprint first
    tie = {"x": np.array([1.0], np.float32), "m": np.array([-1.0], np.float32)}
    assert knn_fingerprints(tie, np.zeros(1, np.float32), 1) == ["m"]


def test_meta_features_deterministic():
    X, y = _make_data(7)
    coded = factorize(X, y)
    f1, f2 = meta_features(coded), meta_features(coded)
    assert f1.shape == (len(META_FEATURE_NAMES),)
    assert f1.dtype == np.float32
    assert f1.tobytes() == f2.tobytes()


# ---------------------------------------------------------------------------
# engine seeding
# ---------------------------------------------------------------------------

_CFG = AutoMLConfig(n_trials=6, rungs=(4, 8))


def test_search_init_none_seed_is_cold_path():
    X, y = _make_data(3)
    a = search_init(X, y, config=_CFG)
    b = search_init(X, y, config=_CFG, seed_trials=None)
    c = search_init(X, y, config=_CFG, seed_trials=[])
    for other in (b, c):
        assert other.specs == a.specs
        assert other.alive_ids == a.alive_ids
        assert other.trial_rung == a.trial_rung


def test_seeded_subset_rung0_bit_identical():
    X, y = _make_data(11)
    cold = search_init(X, y, config=_CFG)
    search_eval_rung(cold)
    cold_accs = {spec: float(v) for spec, v, *_ in cold.live}

    seeds = [cold.specs[1], cold.specs[4]]
    warm = search_init(X, y, config=_CFG, seed_trials=seeds)
    assert warm.alive_ids == [1, 4]        # sampled trial ids preserved
    assert warm.specs == cold.specs        # population untouched
    search_eval_rung(warm)
    assert len(warm.live) == 2
    for spec, v, *_ in warm.live:
        assert float(v) == cold_accs[spec]   # bitwise: same (seed, tid, rung)


def test_unmatched_seed_appends_fresh_id():
    X, y = _make_data(11)
    cold = search_init(X, y, config=_CFG)
    novel = PipelineSpec(preproc="none", feature_frac=1.0,
                         family=cold.specs[0].family, hp=cold.specs[0].hp)
    if novel in cold.specs:   # make it genuinely novel
        novel = PipelineSpec(preproc="standard", feature_frac=0.5,
                             family=cold.specs[0].family,
                             hp=cold.specs[0].hp)
    assert novel not in cold.specs
    warm = search_init(X, y, config=_CFG,
                       seed_trials=[cold.specs[2], novel])
    n = len(cold.specs)
    assert warm.specs[:n] == cold.specs
    assert warm.specs[n] == novel
    assert warm.alive_ids == [2, n]


# ---------------------------------------------------------------------------
# store persistence
# ---------------------------------------------------------------------------


def test_store_wire_round_trip_bytes_identical():
    store = ExperienceStore()
    rng = np.random.default_rng(0)
    for j in range(3):
        fp = f"fp{j}"
        store.note_meta(fp, rng.normal(size=8).astype(np.float32))
        for i in range(4):
            for rung in (0, 1):
                store.note_trial(fp, _spec(i), rung,
                                 float(rng.uniform(0.3, 1.0)))
        store.note_winner(fp, _spec(j))
    blob = wire.dumps(store.state_dict())
    other = ExperienceStore()
    other.load_state(wire.loads(blob))
    assert wire.dumps(other.state_dict()) == blob
    assert other.trained() == store.trained()
    assert other.matrix() == store.matrix()


def test_store_keeps_best_per_rung():
    store = ExperienceStore()
    store.note_trial("fp", _spec(0), 0, 0.5)
    store.note_trial("fp", _spec(0), 0, 0.8)
    store.note_trial("fp", _spec(0), 0, 0.6)   # worse: ignored
    store.note_trial("fp", _spec(0), 1, 0.7)
    rec = store.records["fp"]
    assert rec.rung_accs[_spec(0)] == {0: 0.8, 1: 0.7}
    assert rec.final_acc(_spec(0)) == 0.7      # deepest rung wins


# ---------------------------------------------------------------------------
# scheduler end-to-end
# ---------------------------------------------------------------------------

_SUB = AutoMLConfig(n_trials=6, rungs=(4, 8))
_WARM_PLAN = plan("mc", budget=120, fine_tune=False, sub_automl=_SUB)
_COLD_PLAN = plan("mc", budget=120, fine_tune=False, sub_automl=_SUB,
                  warm_start=False)


def _run_jobs(sched, datasets, p):
    ids = [sched.submit(X, y, plan=p) for X, y in datasets]
    sched.run()
    out = []
    for jid in ids:
        job = sched.jobs[jid]
        assert job.phase == "done", repr(job.error)
        out.append(job.result)
    return out


@pytest.fixture(scope="module")
def trained_scheduler():
    sched = Scheduler(warm_min_history=10)   # feed only, never self-warm
    _run_jobs(sched, [_make_data(30 + i) for i in range(3)], _WARM_PLAN)
    return sched


def test_scheduler_feeds_experience(trained_scheduler):
    store = trained_scheduler.experience
    assert store.n_trained() == 3
    for fp in store.trained():
        rec = store.records[fp]
        assert rec.winner is not None
        assert rec.features is not None
        assert len(rec.rung_accs) > 0


def test_snapshot_preserves_store_and_decisions(trained_scheduler):
    blob = trained_scheduler.snapshot()
    restored = Scheduler()
    restored.load_snapshot(blob)
    a = trained_scheduler.experience.state_dict()
    b = restored.experience.state_dict()
    assert wire.dumps(a) == wire.dumps(b)
    X, y = _make_data(77)
    feats = meta_features(factorize(X, y))
    assert (portfolio_for(trained_scheduler.experience, feats, k=4, knn=2)
            == portfolio_for(restored.experience, feats, k=4, knn=2))


def test_warm_reaches_cold_winner_with_fewer_trials(trained_scheduler):
    evals = [_make_data(90 + i) for i in range(2)]
    cold = _run_jobs(Scheduler(), evals, _COLD_PLAN)

    # portfolio_k below the cold population size, else nothing is saved
    warm_sched = Scheduler(warm_min_history=3, portfolio_k=4)
    warm_sched.experience.load_state(
        trained_scheduler.experience.state_dict())
    warm = _run_jobs(warm_sched, evals, _WARM_PLAN)

    assert warm_sched.m_portfolio_hits.value() == len(evals)
    for c, w in zip(cold, warm):
        assert (float(w.intermediate.val_acc)
                >= float(c.intermediate.val_acc) - 1e-6)
    assert (sum(w.intermediate.n_trials for w in warm)
            < sum(c.intermediate.n_trials for c in cold))


def test_plan_opt_out_is_cold_identical(trained_scheduler):
    data = [_make_data(123)]
    cold = _run_jobs(Scheduler(), data, _COLD_PLAN)[0]

    opted = Scheduler(warm_min_history=3)
    opted.experience.load_state(trained_scheduler.experience.state_dict())
    out = _run_jobs(opted, data, _COLD_PLAN)[0]

    assert opted.m_portfolio_hits.value() == 0
    assert out.intermediate.spec == cold.intermediate.spec
    assert (float(out.intermediate.val_acc)
            == float(cold.intermediate.val_acc))
    assert out.intermediate.n_trials == cold.intermediate.n_trials
    assert ([float(a) for _s, a in out.intermediate.trials]
            == [float(a) for _s, a in cold.intermediate.trials])


# ---------------------------------------------------------------------------
# rate limiting
# ---------------------------------------------------------------------------


def test_token_bucket_deterministic_clock():
    t = [0.0]
    bucket = TokenBucket(rate=2.0, burst=3.0, clock=lambda: t[0])
    assert [bucket.try_acquire() for _ in range(3)] == [0.0, 0.0, 0.0]
    retry = bucket.try_acquire()
    assert retry == pytest.approx(0.5)     # 1 token / 2 per s
    t[0] += 0.5
    assert bucket.try_acquire() == 0.0
    t[0] += 100.0                          # refill caps at burst
    assert bucket.tokens == pytest.approx(3.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)


def test_server_submit_rate_limited():
    t = [0.0]
    srv = SubStratServer(tenant_rate_limits={"a": (1.0, 2.0)},
                         rate_clock=lambda: t[0])
    X, y = _make_data(5, N=40)
    srv.submit(X, y, tenant="a")
    srv.submit(X, y, tenant="a")
    with pytest.raises(RateLimited) as exc:
        srv.submit(X, y, tenant="a")
    assert exc.value.retry_after_s == pytest.approx(1.0)
    srv.submit(X, y, tenant="b")           # unlimited tenant unaffected
    t[0] += 1.0
    srv.submit(X, y, tenant="a")           # bucket refilled
    text = srv.metrics_text()
    assert 'rate_limited_total{tenant="a"} 1' in text
    assert srv.stats()["rate_limits"]["a"]["burst"] == 2.0


def test_http_submit_429_retry_after():
    from repro.service.transport import SubStratHTTPServer

    t = [0.0]
    srv = SubStratServer(default_rate_limit=(0.5, 1.0),
                         rate_clock=lambda: t[0])
    http = SubStratHTTPServer(srv).start()
    try:
        X, y = _make_data(5, N=40)
        payload = wire.dumps({"X": X, "y": y, "tenant": "t", "key": None,
                              "plan": _COLD_PLAN, "X_test": None,
                              "y_test": None}, kind="submit")

        def post():
            req = urllib.request.Request(
                http.url + "/v1/submit", data=payload,
                headers={"Content-Type": "application/x-substrat-wire"})
            try:
                with urllib.request.urlopen(req, timeout=30) as resp:
                    return resp.status, dict(resp.headers), resp.read()
            except urllib.error.HTTPError as e:
                return e.code, dict(e.headers), e.read()

        status, _headers, _body = post()
        assert status == 200
        status, headers, body = post()
        assert status == 429
        assert int(headers["Retry-After"]) == 2     # ceil(1/0.5)
        assert b"retry_after_s" in body
    finally:
        http.close()
