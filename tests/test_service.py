"""Service layer (DESIGN.md §11): fingerprinting, the LRU DST cache, the
phase scheduler with cross-job rung merging, and the serving front end.

The headline assertions are the PR's acceptance criteria: merged cross-job
execution is parity-exact with per-job sequential ``substrat()`` (same
winner spec, trial accuracies within 1e-6), and a repeat submission's DST
phase is a cache lookup (>= 90% of the Gen-DST time skipped)."""
import numpy as np
import pytest

import jax

from repro.automl.engine import AutoMLConfig
from repro.core.gen_dst import GenDSTConfig
from repro.core.measures import factorize
from repro.core.substrat import SubStratConfig, substrat
from repro.service import (
    BudgetExceeded, DSTCache, DSTCacheEntry, SubStratServer,
    dataset_fingerprint,
)
from repro.service.cache import dst_cache_key


def _make(seed, N=700, d=8):
    r = np.random.default_rng(seed)
    y = r.integers(0, 2, N)
    X = np.column_stack(
        [y * 1.5 + r.normal(0, 0.8, N) for _ in range(d)]).astype(np.float32)
    return X, y


CFG = SubStratConfig(
    gen=GenDSTConfig(psi=4, phi=8),
    sub_automl=AutoMLConfig(n_trials=6, rungs=(15, 40)),
    ft_automl=AutoMLConfig(n_trials=4, rungs=(40,)),
)


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------


def test_fingerprint_stable_and_content_sensitive():
    X, y = _make(0)
    fp1 = dataset_fingerprint(factorize(X, y))
    fp2 = dataset_fingerprint(factorize(X.copy(), y.copy()))
    assert fp1 == fp2                      # content hash, not object identity

    X2 = X.copy()
    X2[0, 0] += 100.0                      # changes that column's codes
    assert dataset_fingerprint(factorize(X2, y)) != fp1

    y2 = 1 - y                             # same columns, different target
    assert dataset_fingerprint(factorize(X, y2)) != fp1


# ---------------------------------------------------------------------------
# LRU DST cache
# ---------------------------------------------------------------------------


def _entry(i):
    return DSTCacheEntry(row_idx=np.arange(i + 1), col_mask=np.ones(3, bool),
                         fitness=-float(i))


def test_cache_lru_eviction_and_recency():
    cache = DSTCache(capacity=2)
    ka, kb, kc = (dst_cache_key(fp, 4, 2, "entropy") for fp in "abc")
    cache.put(ka, _entry(0))
    cache.put(kb, _entry(1))
    assert cache.get(ka) is not None       # refreshes a's recency
    cache.put(kc, _entry(2))               # evicts b (least recent)
    assert kb not in cache and ka in cache and kc in cache
    assert cache.get(kb) is None
    stats = cache.stats()
    assert stats["evictions"] == 1 and stats["size"] == 2
    assert stats["hits"] == 1 and stats["misses"] == 1


def test_cache_note_winner():
    cache = DSTCache(capacity=2)
    key = dst_cache_key("fp", 4, 2, "entropy")
    cache.put(key, _entry(0))
    cache.note_winner(key, "mlp")
    assert cache.get(key).winner_family == "mlp"
    cache.note_winner(dst_cache_key("gone", 4, 2, "entropy"), "gnb")  # no-op


# ---------------------------------------------------------------------------
# scheduler: cross-job merge parity + caching  (acceptance criteria)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def datasets():
    return _make(1), _make(2)


@pytest.fixture(scope="module")
def served(datasets):
    """Two distinct-dataset jobs run concurrently (merged rungs), plus a
    repeat of the first (cache path); warm_start off so every job runs the
    full 3-step pipeline and stays comparable to sequential substrat()."""
    (XA, yA), (XB, yB) = datasets
    srv = SubStratServer(warm_start=False)
    ids = [
        srv.submit(XA, yA, key=jax.random.key(0), config=CFG),
        srv.submit(XB, yB, key=jax.random.key(1), config=CFG),
        srv.submit(XA, yA, key=jax.random.key(2), config=CFG),
    ]
    srv.run()
    return srv, ids


def test_jobs_complete_and_rungs_merge(served):
    srv, ids = served
    assert all(srv.poll(j).done for j in ids)
    stats = srv.stats()
    # concurrent compatible jobs must actually merge, not run solo
    assert stats["merged_rungs"] >= 1
    assert stats["merged_jobs"] > stats["merged_rungs"]


def test_merged_parity_with_sequential_substrat(served, datasets):
    """Acceptance: cross-job batched results equal per-job sequential
    execution — same winner spec, trial accuracies within 1e-6."""
    srv, ids = served
    (XA, yA), (XB, yB) = datasets
    for jid, (X, y), key in ((ids[0], (XA, yA), 0), (ids[1], (XB, yB), 1)):
        seq = substrat(X, y, key=jax.random.key(key), config=CFG)
        got = srv.result(jid)
        assert got.final.spec == seq.final.spec
        assert got.intermediate.spec == seq.intermediate.spec
        np.testing.assert_array_equal(got.row_idx, seq.row_idx)
        np.testing.assert_array_equal(got.col_idx, seq.col_idx)
        for pass_got, pass_seq in ((got.intermediate, seq.intermediate),
                                   (got.final, seq.final)):
            assert [s for s, _ in pass_got.trials] == [s for s, _ in pass_seq.trials]
            np.testing.assert_allclose([v for _, v in pass_got.trials],
                                       [v for _, v in pass_seq.trials],
                                       atol=1e-6)


def test_repeat_submission_skips_gen_dst(served):
    """Acceptance: a cache hit skips >= 90% of the Gen-DST phase time."""
    srv, ids = served
    first, repeat = srv.poll(ids[0]), srv.poll(ids[2])
    assert not first.cache_hit and repeat.cache_hit
    assert repeat.times["gen_dst_s"] <= 0.1 * first.times["gen_dst_s"]
    # and the repeat reuses the identical subset
    np.testing.assert_array_equal(srv.result(ids[2]).row_idx,
                                  srv.result(ids[0]).row_idx)


def test_cache_keyed_by_search_config(datasets):
    """A subset found by a weaker Gen-DST search must not satisfy a repeat
    submission that asks for a stronger search."""
    import dataclasses
    (XA, yA), _ = datasets
    srv = SubStratServer()
    srv.submit(XA, yA, config=CFG)
    srv.run()
    stronger = dataclasses.replace(CFG, gen=GenDSTConfig(psi=6, phi=12))
    b = srv.submit(XA, yA, config=stronger)
    srv.run()
    assert not srv.poll(b).cache_hit
    assert srv.stats()["cache"]["size"] == 2


def test_warm_start_skips_sub_automl(datasets):
    """A repeat arriving after the winner family is known jumps straight to
    the restricted fine-tune (warm_start is the production default)."""
    (XA, yA), _ = datasets
    srv = SubStratServer()
    first = srv.submit(XA, yA, key=jax.random.key(0), config=CFG)
    prior = srv.result(first)
    late = srv.submit(XA, yA, key=jax.random.key(7), config=CFG)
    res = srv.result(late)
    status = srv.poll(late)
    assert status.cache_hit and status.warm_started
    assert "automl_sub_s" not in status.times
    assert res.intermediate is res.final
    assert res.final.spec.family == prior.intermediate.spec.family


def test_concurrent_repeats_wait_and_warm_start(datasets):
    """A concurrent duplicate submission parks in warm_wait instead of
    duplicating the sub-AutoML pass, then warm-starts off the leader's
    winner family (in-flight dedup)."""
    (XA, yA), _ = datasets
    srv = SubStratServer()
    a = srv.submit(XA, yA, key=jax.random.key(0), config=CFG)
    b = srv.submit(XA, yA, key=jax.random.key(1), config=CFG)
    srv.run()
    sa, sb = srv.poll(a), srv.poll(b)
    assert not sa.cache_hit and sb.cache_hit and sb.warm_started
    assert "automl_sub_s" in sa.times and "automl_sub_s" not in sb.times
    assert (srv.result(b).final.spec.family
            == srv.result(a).intermediate.spec.family)


def test_loop_backend_jobs_run_solo(datasets):
    """Jobs the merged dispatch can't take (loop backend) still complete."""
    (XA, yA), _ = datasets
    import dataclasses
    cfg = dataclasses.replace(CFG, automl_backend="loop",
                              sub_automl=AutoMLConfig(n_trials=4, rungs=(15,)),
                              ft_automl=AutoMLConfig(n_trials=4, rungs=(15,)))
    srv = SubStratServer()
    jid = srv.submit(XA, yA, config=cfg)
    res = srv.result(jid)
    assert res.final.val_acc > 0
    stats = srv.stats()
    assert stats["solo_rungs"] >= 1 and stats["merged_rungs"] == 0


# ---------------------------------------------------------------------------
# server front end: budgets, failure isolation
# ---------------------------------------------------------------------------


def test_tenant_budget_enforced(datasets):
    (XA, yA), (XB, yB) = datasets
    srv = SubStratServer(tenant_budgets={"alice": 1e-6})
    jid = srv.submit(XA, yA, tenant="alice", config=CFG)   # admitted: no spend yet
    srv.run()
    assert srv.poll(jid).done                 # admitted jobs run to completion
    with pytest.raises(BudgetExceeded):
        srv.submit(XA, yA, tenant="alice", config=CFG)
    # other tenants are unaffected
    jid2 = srv.submit(XB, yB, tenant="bob", config=CFG)
    assert srv.result(jid2).final is not None
    spent = srv.stats()["tenants"]["alice"]["spent_s"]
    assert spent > 1e-6


def test_failed_job_is_isolated(datasets):
    (XA, yA), (XB, yB) = datasets

    def bad_dst(key, coded, n, m):
        raise RuntimeError("boom")

    srv = SubStratServer()
    bad = srv.submit(XA, yA, config=CFG, dst_fn=bad_dst)
    good = srv.submit(XB, yB, config=CFG)
    srv.run()
    assert srv.poll(bad).phase == "failed"
    assert "boom" in srv.poll(bad).error
    assert srv.poll(good).done
    with pytest.raises(RuntimeError):
        srv.result(bad)


def test_custom_dst_fn_bypasses_cache(datasets):
    """dst_fn outputs are not Gen-DST outputs: they must not be cached."""
    from repro.core.gen_dst import random_dst
    (XA, yA), _ = datasets
    srv = SubStratServer()
    a = srv.submit(XA, yA, config=CFG, dst_fn=random_dst)
    b = srv.submit(XA, yA, config=CFG, dst_fn=random_dst)
    srv.run()
    assert not srv.poll(a).cache_hit and not srv.poll(b).cache_hit
    assert srv.stats()["cache"]["size"] == 0
