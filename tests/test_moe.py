"""MoE dispatch: sort-based capacity dispatch vs one-hot dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env — deterministic fallback, same API subset
    from _hyp_fallback import given, settings, strategies as st

from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_block, moe_block_dense, route_topk


def _cfg(E=8, k=2, cf=8.0, shared=0):
    return ModelConfig(
        "moe-test", "moe", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        head_dim=8, d_ff=16, vocab_size=64, n_experts=E, moe_top_k=k,
        n_shared_experts=shared, capacity_factor=cf,
    )


def test_dispatch_matches_dense_when_capacity_ample():
    cfg = _cfg(E=8, k=2, cf=16.0, shared=1)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    y_fast = moe_block(p, x, cfg)
    y_dense = moe_block_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)


def test_route_topk_weights():
    logits = jax.random.normal(jax.random.key(0), (32, 8))
    idx, w = route_topk(logits, 3)
    assert idx.shape == (32, 3) and w.shape == (32, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(32), atol=1e-5)
    assert (np.asarray(w) >= 0).all()
    # indices are distinct per token
    idxs = np.asarray(idx)
    assert all(len(set(r)) == 3 for r in idxs)


def test_capacity_drops_tokens():
    """With tiny capacity, output magnitude shrinks (dropped tokens get 0
    from routed experts) but never NaNs."""
    cfg_tight = _cfg(E=4, k=2, cf=0.25)
    cfg_ample = _cfg(E=4, k=2, cf=16.0)
    p = init_moe(jax.random.key(0), cfg_tight)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32), jnp.float32)
    y_tight = moe_block(p, x, cfg_tight)
    y_ample = moe_block(p, x, cfg_ample)
    assert bool(jnp.isfinite(y_tight).all())
    assert float(jnp.abs(y_tight).sum()) < float(jnp.abs(y_ample).sum())


def test_moe_grad_flows_to_router():
    cfg = _cfg(E=4, k=2, cf=8.0)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, 32), jnp.float32)

    def loss(params):
        return (moe_block(params, x, cfg) ** 2).sum()

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0, "router must receive gradient"
    assert float(jnp.abs(g["e_up"]).max()) > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(1, 3), st.integers(0, 100))
def test_dispatch_conservation(E, k, seed):
    """Every kept (expert, slot) holds a real token id with its weight; total
    dispatched weight <= total routed weight."""
    cfg = _cfg(E=E, k=min(k, E), cf=1.0)
    p = init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (1, 16, 32), jnp.float32)
    y = moe_block(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
