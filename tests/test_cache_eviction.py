"""Cost-aware DST-cache eviction (DESIGN.md §12.5): GDSF priority scoring
(production cost in strategy seconds, entry size in bytes) and the byte
budget, closing the ROADMAP eviction item."""
import numpy as np
import pytest

from repro.service import DSTCache, DSTCacheEntry
from repro.service.cache import dst_cache_key


def _entry(n_rows=8, cost_s=1.0):
    return DSTCacheEntry(row_idx=np.zeros(n_rows, np.int64),
                         col_mask=np.ones(4, bool), fitness=-0.1,
                         cost_s=cost_s)


def _key(tag):
    return dst_cache_key(tag, 4, 2, "entropy")


def test_entry_nbytes_counts_payload():
    e = _entry(n_rows=16)
    assert e.nbytes == 16 * 8 + 4


def test_invalid_policy_and_budget_rejected():
    with pytest.raises(ValueError, match="policy"):
        DSTCache(policy="fifo")
    with pytest.raises(ValueError, match="byte_budget"):
        DSTCache(byte_budget=0)


def test_gdsf_evicts_cheap_entry_first():
    """Same size, same recency: the entry that took 100x longer to produce
    survives — plain LRU would evict by age instead."""
    cache = DSTCache(capacity=2, policy="gdsf")
    cache.put(_key("expensive"), _entry(cost_s=10.0))
    cache.put(_key("cheap"), _entry(cost_s=0.1))
    cache.put(_key("new"), _entry(cost_s=1.0))       # forces one eviction
    assert _key("expensive") in cache
    assert _key("cheap") not in cache
    assert cache.stats()["evictions"] == 1


def test_gdsf_frequency_rescues_hot_cheap_entry():
    """A cheap but frequently-hit entry outranks a cold expensive one when
    hits * cost compensate — the F in GDSF."""
    cache = DSTCache(capacity=2, policy="gdsf")
    cache.put(_key("cold_costly"), _entry(cost_s=1.0))
    cache.put(_key("hot_cheap"), _entry(cost_s=0.3))
    for _ in range(10):
        assert cache.get(_key("hot_cheap")) is not None
    # 11 uses x 0.3s outrank 1 use x 1.0s; the 2.0s newcomer outranks both
    cache.put(_key("new"), _entry(cost_s=2.0))
    assert _key("hot_cheap") in cache
    assert _key("cold_costly") not in cache


def test_gdsf_size_term_prefers_small_entries():
    """Equal cost and recency: the byte-heavy entry is the victim."""
    cache = DSTCache(capacity=2, policy="gdsf")
    cache.put(_key("huge"), _entry(n_rows=4096, cost_s=1.0))
    cache.put(_key("small"), _entry(n_rows=8, cost_s=1.0))
    cache.put(_key("new"), _entry(n_rows=8, cost_s=1.0))
    assert _key("small") in cache and _key("huge") not in cache


def test_gdsf_clock_ages_out_stale_priorities():
    """Eviction advances the clock, so a fresh cheap entry eventually
    outranks entries whose priority was set long ago (no permanent squatters)."""
    cache = DSTCache(capacity=2, policy="gdsf")
    cache.put(_key("old_costly"), _entry(cost_s=5.0))
    cache.put(_key("other"), _entry(cost_s=4.0))
    # stream of singles: each eviction raises the clock toward the old
    # priorities until the un-hit "old_costly" entry is displaced
    for i in range(200):
        cache.put(_key(f"s{i}"), _entry(cost_s=0.5))
        if _key("old_costly") not in cache:
            break
    assert _key("old_costly") not in cache


def test_byte_budget_enforced_lru():
    e = _entry(n_rows=8)          # 68 bytes each
    cache = DSTCache(capacity=100, byte_budget=3 * e.nbytes)
    for tag in "abcd":
        cache.put(_key(tag), _entry(n_rows=8))
    assert cache.total_bytes <= 3 * e.nbytes
    assert len(cache) == 3
    assert _key("a") not in cache                  # LRU victim
    assert cache.stats()["bytes"] == cache.total_bytes


def test_byte_budget_enforced_gdsf():
    e = _entry(n_rows=8)
    cache = DSTCache(capacity=100, byte_budget=2 * e.nbytes, policy="gdsf")
    cache.put(_key("costly"), _entry(cost_s=10.0))
    cache.put(_key("cheap1"), _entry(cost_s=0.1))
    cache.put(_key("cheap2"), _entry(cost_s=0.2))
    assert len(cache) == 2
    assert _key("costly") in cache                 # cheap one was the victim


def test_byte_budget_keeps_last_entry():
    """An over-budget single entry is kept: the cache never evicts down to
    empty (the entry was just paid for; serving it beats rerunning)."""
    cache = DSTCache(capacity=4, byte_budget=8)
    cache.put(_key("big"), _entry(n_rows=64))
    assert len(cache) == 1


def test_scheduler_records_production_cost():
    """The scheduler stores each search's wall seconds on the entry — the
    GDSF cost term."""
    import jax
    from repro.automl.engine import AutoMLConfig
    from repro.core.gen_dst import GenDSTConfig
    from repro.core.plan import plan
    from repro.service import SubStratServer

    r = np.random.default_rng(0)
    y = r.integers(0, 2, 300)
    X = np.column_stack([y + r.normal(0, 0.5, 300) for _ in range(5)]
                        ).astype(np.float32)
    srv = SubStratServer(cache_policy="gdsf")
    p = plan("gen_dst", cfg=GenDSTConfig(psi=2, phi=4),
             sub_automl=AutoMLConfig(n_trials=4, rungs=(10,)),
             ft_automl=AutoMLConfig(n_trials=4, rungs=(10,)))
    srv.submit(X, y, key=jax.random.key(0), plan=p)
    srv.run()
    entries = list(srv.scheduler.cache._entries.values())
    assert len(entries) == 1 and entries[0].cost_s > 0
