"""Incremental fitness, island parallelism, and the histogram backend switch.

Parity contracts (DESIGN.md §5.5): the incremental count path, the full
recompute path, and both histogram backends must produce *identical* DSTs
for the same key — counts are small integers, so every path is exact in f32
and the GA trajectories coincide bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gen_dst import GenDSTConfig, gen_dst
from repro.core.measures import factorize, subset_entropy
from repro.core.substrat import SubStratConfig
from repro.kernels.entropy.ops import population_histogram


@pytest.fixture(scope="module")
def coded():
    rng = np.random.default_rng(3)
    X = np.column_stack([rng.integers(0, k, 1200)
                         for k in (3, 5, 17, 2, 40, 7, 200)]).astype(float)
    y = rng.integers(0, 2, 1200).astype(float)
    return factorize(X, y)


def _same_dst(r1, r2):
    np.testing.assert_array_equal(np.asarray(r1.row_idx), np.asarray(r2.row_idx))
    np.testing.assert_array_equal(np.asarray(r1.col_mask), np.asarray(r2.col_mask))
    assert float(r1.fitness) == float(r2.fitness)


# ---------------------------------------------------------------------------
# incremental fitness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cross_every", [2, 3])
def test_incremental_matches_full_recompute(coded, cross_every):
    cfg = GenDSTConfig(psi=9, phi=16, cross_every=cross_every, incremental=True)
    r_inc = gen_dst(jax.random.key(5), coded, 30, 3, cfg)
    r_full = gen_dst(jax.random.key(5), coded, 30, 3,
                     cfg._replace(incremental=False))
    _same_dst(r_inc, r_full)


def test_incremental_fitness_is_true_loss(coded):
    """Carried counts must never drift from the gather-from-scratch truth."""
    cfg = GenDSTConfig(psi=10, phi=16, cross_every=5)  # 8 delta-only gens
    res = gen_dst(jax.random.key(2), coded, 25, 3, cfg)
    f_d = float(subset_entropy(coded.codes, res.row_idx, res.col_mask,
                               coded.max_bins))
    assert abs(abs(f_d - float(res.f_ref)) - (-float(res.fitness))) < 1e-5


def test_cross_every_default_matches_invariants(coded):
    """cross_every=1 keeps the seed-faithful shape/pinning invariants."""
    res = gen_dst(jax.random.key(0), coded, 20, 3,
                  GenDSTConfig(psi=6, phi=12, cross_every=1))
    assert int(res.col_mask.sum()) == 3
    assert bool(res.col_mask[coded.target_col])
    assert (np.diff(np.asarray(res.history)) >= -1e-6).all()


# ---------------------------------------------------------------------------
# islands
# ---------------------------------------------------------------------------


def test_island_gen_dst_deterministic(coded):
    cfg = GenDSTConfig(psi=8, phi=8, num_islands=4, migrate_every=3,
                       cross_every=2)
    r1 = gen_dst(jax.random.key(7), coded, 30, 3, cfg)
    r2 = gen_dst(jax.random.key(7), coded, 30, 3, cfg)
    _same_dst(r1, r2)


def test_island_gen_dst_invariants(coded):
    n, m = 30, 3
    cfg = GenDSTConfig(psi=8, phi=8, num_islands=3, migrate_every=2)
    res = gen_dst(jax.random.key(4), coded, n, m, cfg)
    assert res.row_idx.shape == (n,)
    assert int(res.col_mask.sum()) == m
    assert bool(res.col_mask[coded.target_col])
    assert (np.asarray(res.row_idx) >= 0).all()
    assert (np.asarray(res.row_idx) < coded.num_rows).all()
    assert res.history.shape == (cfg.psi,)
    assert (np.diff(np.asarray(res.history)) >= -1e-6).all()
    # best-so-far fitness must still equal the true loss of the best DST
    f_d = float(subset_entropy(coded.codes, res.row_idx, res.col_mask,
                               coded.max_bins))
    assert abs(abs(f_d - float(res.f_ref)) - (-float(res.fitness))) < 1e-5


def test_islands_with_generic_measure(coded):
    cfg = GenDSTConfig(psi=5, phi=8, num_islands=2, migrate_every=2,
                       cross_every=2, measure="pnorm")
    res = gen_dst(jax.random.key(1), coded, 20, 3, cfg)
    assert int(res.col_mask.sum()) == 3
    assert np.isfinite(float(res.fitness))


def test_substrat_config_island_override():
    cfg = SubStratConfig(num_islands=4, dst_backend="pallas")
    gen = cfg.resolved_gen()
    assert gen.num_islands == 4 and gen.backend == "pallas"
    assert SubStratConfig().resolved_gen() == GenDSTConfig()


# ---------------------------------------------------------------------------
# histogram backend switch
# ---------------------------------------------------------------------------


def test_population_histogram_backends_agree():
    rng = np.random.default_rng(0)
    sub = jnp.asarray(rng.integers(0, 11, (13, 40, 5)), jnp.int32)
    h_jnp = population_histogram(sub, 11, backend="jnp")
    h_pal = population_histogram(sub, 11, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(h_jnp), np.asarray(h_pal), atol=1e-4)
    # mass conservation: every candidate/column histogram sums to n rows
    np.testing.assert_allclose(np.asarray(h_pal.sum(-1)), 40.0)


def test_population_histogram_rejects_unknown_backend():
    sub = jnp.zeros((2, 4, 3), jnp.int32)
    with pytest.raises(ValueError, match="backend"):
        population_histogram(sub, 4, backend="cuda")


def test_gen_dst_pallas_backend_matches_jnp(coded):
    cfg = GenDSTConfig(psi=6, phi=12, cross_every=2)
    r_jnp = gen_dst(jax.random.key(5), coded, 25, 3, cfg)
    r_pal = gen_dst(jax.random.key(5), coded, 25, 3,
                    cfg._replace(backend="pallas"))
    _same_dst(r_jnp, r_pal)
