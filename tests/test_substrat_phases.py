"""SubStrat phase functions: the degenerate-label subset patch, the
SubStrat-NF test-evaluation path (DST-column-restricted accuracy), and the
``dst_fn`` baseline-injection path."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.automl.engine import AutoMLConfig, apply_pipeline
from repro.automl.models import accuracy
from repro.core.gen_dst import DSTResult, GenDSTConfig
from repro.core.substrat import (
    SubStratConfig, build_subset, nf_test_eval, substrat,
)

SMALL_CFG = SubStratConfig(
    gen=GenDSTConfig(psi=4, phi=8),
    sub_automl=AutoMLConfig(n_trials=5, rungs=(15, 40)),
    ft_automl=AutoMLConfig(n_trials=4, rungs=(40,)),
)


# ---------------------------------------------------------------------------
# build_subset: degenerate-label patch draws from the missing class(es)
# ---------------------------------------------------------------------------


def _skewed_data(N=500, d=4, minority=3):
    """Binary labels where class 1 exists only in the last ``minority`` rows,
    far outside any small fixed-seed draw's likely reach."""
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (N, d)).astype(np.float32)
    y = np.zeros(N, np.int64)
    y[-minority:] = 1
    return X, y


def test_build_subset_patches_missing_class():
    X, y = _skewed_data()
    row_idx = np.arange(50)          # all majority-class rows
    col_idx = np.arange(3)
    X_sub, y_sub = build_subset(X, y, row_idx, col_idx, jax.random.key(0))
    # every class of y must be present — drawn explicitly from class rows,
    # not hoped-for via a fixed random draw (which misses a 3-row minority
    # with probability ~(1 - 3/500)^64 ≈ 68%)
    assert set(np.unique(y_sub)) == {0, 1}
    assert X_sub.shape[1] == 3
    # patched rows carry the right features for their labels
    patched = y_sub[len(row_idx):]
    assert (patched == 1).sum() == 3     # all 3 minority rows drawn


def test_build_subset_patch_seeded_from_run_key():
    X, y = _skewed_data(minority=40)
    row_idx, col_idx = np.arange(50), np.arange(3)
    a1 = build_subset(X, y, row_idx, col_idx, jax.random.key(5))
    a2 = build_subset(X, y, row_idx, col_idx, jax.random.key(5))
    b = build_subset(X, y, row_idx, col_idx, jax.random.key(6))
    np.testing.assert_array_equal(a1[1], a2[1])        # deterministic per key
    np.testing.assert_array_equal(a1[0], a2[0])
    # a different run key draws a different minority sample (40 choose 32
    # leaves plenty of room; identical draws would mean the key is ignored)
    assert not np.array_equal(a1[0], b[0])


def test_build_subset_no_patch_when_all_classes_present():
    X, y = _skewed_data()
    row_idx = np.concatenate([np.arange(20), [len(y) - 1]])  # incl. a minority row
    X_sub, y_sub = build_subset(X, y, row_idx, np.arange(2), jax.random.key(0))
    assert len(y_sub) == len(row_idx)                  # nothing appended


def test_build_subset_multiclass_patch():
    X, y = _skewed_data()
    y = y.copy()
    y[-1] = 2                        # classes {0, 1, 2}; rows cover only 0
    X_sub, y_sub = build_subset(X, y, np.arange(30), np.arange(2),
                                jax.random.key(1))
    assert set(np.unique(y_sub)) == {0, 1, 2}


def test_build_subset_degenerate_many_missing_classes():
    """Tiny subset, many classes: when nearly *every* class is missing the
    patch must not over-draw — one representative per missing class, not 32
    — so the patched subset stays subset-sized instead of ballooning into a
    large fraction of the full data."""
    rng = np.random.default_rng(0)
    N, C = 640, 16
    y = np.repeat(np.arange(C), N // C)       # 40 rows per class
    X = rng.normal(0, 1, (N, 3)).astype(np.float32)
    row_idx = np.arange(4)                    # covers only class 0
    X_sub, y_sub = build_subset(X, y, row_idx, np.arange(2), jax.random.key(2))
    assert set(np.unique(y_sub)) == set(range(C))     # every class present
    # 15 missing classes x 1 row each — not 15 x 32 = 480 rows
    assert len(y_sub) == len(row_idx) + (C - 1)
    assert X_sub.shape == (len(y_sub), 2)


def test_build_subset_empty_rows_still_covers_classes():
    """The fully degenerate case — an empty row draw — patches one row per
    class instead of looping or over-drawing."""
    rng = np.random.default_rng(1)
    y = np.repeat(np.arange(5), 50)
    X = rng.normal(0, 1, (250, 4)).astype(np.float32)
    X_sub, y_sub = build_subset(X, y, np.arange(0), np.arange(3),
                                jax.random.key(0))
    assert set(np.unique(y_sub)) == set(range(5))
    assert len(y_sub) == 5                    # exactly one per missing class


# ---------------------------------------------------------------------------
# SubStrat-NF: DST-column-restricted test accuracy
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def learnable():
    rng = np.random.default_rng(3)
    N = 600
    # non-contiguous label values exercise the class re-encoding
    y = np.where(rng.uniform(size=N) < 0.5, 2, 9)
    X = np.column_stack([
        (y == 9) * 2.0 + rng.normal(0, 0.6, N) for _ in range(6)
    ]).astype(np.float32)
    return X[:480], y[:480], X[480:], y[480:]


def test_nf_test_eval_matches_manual_restricted_accuracy(learnable):
    Xtr, ytr, Xte, yte = learnable
    cfg = dataclasses.replace(SMALL_CFG, fine_tune=False)
    res = substrat(Xtr, ytr, key=jax.random.key(0), config=cfg,
                   X_test=Xte, y_test=yte)
    assert res.final.test_acc is not None
    # recompute: M' applied to the test data restricted to the DST's columns
    inter = res.intermediate
    Xt = apply_pipeline(inter.spec, inter.pre_stats, inter.feat_idx,
                        np.asarray(Xte, np.float32)[:, res.col_idx])
    yt = jnp.asarray(np.searchsorted(np.asarray([2, 9]), yte))
    manual = accuracy(inter.params, Xt, yt, inter.spec.family)
    assert res.final.test_acc == pytest.approx(float(manual), abs=1e-7)
    assert res.final.test_acc > 0.6      # the restricted eval is meaningful


def test_nf_test_eval_unit(learnable):
    """nf_test_eval in isolation: re-encodes labels via the subset's class
    set and restricts columns before applying the pipeline."""
    Xtr, ytr, Xte, yte = learnable
    cfg = dataclasses.replace(SMALL_CFG, fine_tune=False)
    res = substrat(Xtr, ytr, key=jax.random.key(1), config=cfg)
    y_sub_like = np.asarray([2, 9])      # classes present in any valid subset
    out = nf_test_eval(res.intermediate, y_sub_like, res.col_idx, Xte, yte)
    assert out.test_acc is not None and 0.0 <= out.test_acc <= 1.0
    assert out.spec == res.intermediate.spec     # only test_acc replaced


# ---------------------------------------------------------------------------
# dst_fn baseline injection
# ---------------------------------------------------------------------------


def test_dst_fn_injection_controls_subset(learnable):
    """A custom dst_fn's rows/columns are used verbatim by the strategy."""
    Xtr, ytr, Xte, yte = learnable
    M = Xtr.shape[1] + 1                 # factorize appends the target column
    fixed_rows = np.arange(40, dtype=np.int32)
    col_mask = np.zeros(M, bool)
    col_mask[[0, 2, M - 1]] = True       # two features + the target column

    def fixed_dst(key, coded, n, m):
        return DSTResult(jnp.asarray(fixed_rows), jnp.asarray(col_mask),
                         jnp.float32(-0.25), jnp.zeros((0,)), jnp.float32(0.0))

    res = substrat(Xtr, ytr, key=jax.random.key(0), config=SMALL_CFG,
                   dst_fn=fixed_dst, X_test=Xte, y_test=yte)
    np.testing.assert_array_equal(res.row_idx, fixed_rows)
    np.testing.assert_array_equal(res.col_idx, [0, 2])   # target dropped
    assert res.dst_fitness == pytest.approx(-0.25)
    assert res.final.test_acc is not None


def test_dst_fn_target_only_mask_falls_back(learnable):
    """A degenerate mask selecting only the target column falls back to one
    feature column instead of producing an empty subset."""
    Xtr, ytr, _, _ = learnable
    M = Xtr.shape[1] + 1

    def target_only(key, coded, n, m):
        mask = np.zeros(M, bool)
        mask[M - 1] = True
        return DSTResult(jnp.arange(30, dtype=jnp.int32), jnp.asarray(mask),
                         jnp.float32(-1.0), jnp.zeros((0,)), jnp.float32(0.0))

    res = substrat(Xtr, ytr, key=jax.random.key(0), config=SMALL_CFG,
                   dst_fn=target_only)
    assert res.col_idx.tolist() == [0]
