"""Serving correctness: incremental decode with caches must reproduce the
full-sequence forward logits (the KV-cache / SSM-state invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models import encdec, lm

B, S, V = 2, 16, 64


def _decode_all(params, cfg, toks, prompt_len, max_len, extra=None):
    batch = {"tokens": toks[:, :prompt_len]}
    if extra:
        batch.update(extra)
    logits, cache = lm.prefill(params, batch, cfg, max_len=max_len)
    outs = [logits[:, 0]]
    offset = extra["patch_embeds"].shape[1] if extra else 0
    for t in range(prompt_len, toks.shape[1]):
        lg, cache = lm.decode(params, cache, toks[:, t:t + 1],
                              jnp.int32(t + offset), cfg)
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1)   # (B, S-prompt_len+1, V)


CASES = [
    ModelConfig("dense", "dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=8, d_ff=64, vocab_size=V, qk_norm=True, remat=False, dtype="float32"),
    ModelConfig("moe", "moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                head_dim=8, d_ff=32, vocab_size=V, n_experts=4, moe_top_k=2,
                n_shared_experts=1, capacity_factor=4.0, remat=False, dtype="float32"),
    ModelConfig("ssm", "ssm", n_layers=2, d_model=32, vocab_size=V,
                ssm_state=8, ssm_head_dim=8, ssm_chunk=4, remat=False, dtype="float32"),
    ModelConfig("hybrid", "hybrid", n_layers=4, d_model=32, n_heads=4, n_kv_heads=4,
                head_dim=8, d_ff=64, vocab_size=V, ssm_state=8, ssm_head_dim=8,
                ssm_chunk=4, shared_attn_every=2, remat=False, dtype="float32"),
]


@pytest.mark.parametrize("cfg", CASES, ids=[c.name for c in CASES])
def test_decode_matches_forward(cfg):
    params = lm.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    full = lm.forward(params, {"tokens": toks}, cfg)        # (B, S, V)
    prompt = S // 2
    dec = _decode_all(params, cfg, toks, prompt, max_len=S)
    # decode step t produces logits for position t; compare to full fwd
    ref = full[:, prompt - 1:, :]
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(ref, np.float32),
        atol=0.01, rtol=0.01,
    )


def test_vlm_decode_matches_forward():
    cfg = ModelConfig("vlm", "vlm", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=4, head_dim=8, d_ff=64, vocab_size=V,
                      n_img_tokens=4, remat=False, dtype="float32")
    params = lm.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    pe = jax.random.normal(jax.random.key(2), (B, 4, 32), jnp.bfloat16)
    full = lm.forward(params, {"tokens": toks, "patch_embeds": pe}, cfg)
    prompt = S // 2
    dec = _decode_all(params, cfg, toks, prompt, max_len=S + 4,
                      extra={"patch_embeds": pe})
    ref = full[:, prompt - 1:, :]
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref, np.float32), atol=0.05, rtol=0.05)


def test_encdec_decode_matches_forward():
    cfg = ModelConfig("encdec", "encdec", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=4, head_dim=8, d_ff=64, vocab_size=V,
                      n_enc_layers=2, act="gelu", glu=False, max_dec_len=S,
                      remat=False, dtype="float32")
    params = encdec.init_params(jax.random.key(0), cfg)
    frames = jax.random.normal(jax.random.key(1), (B, 24, 32))
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, V)
    full = encdec.forward(params, {"frames": frames, "tokens": toks}, cfg)
    prompt = S // 2
    logits, cache = encdec.prefill(
        params, {"frames": frames, "tokens": toks[:, :prompt]}, cfg,
        max_dec_len=S)
    outs = [logits[:, 0]]
    for t in range(prompt, S):
        lg, cache = encdec.decode(params, cache, toks[:, t:t + 1], jnp.int32(t), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    ref = full[:, prompt - 1:, :]
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref, np.float32), atol=0.05, rtol=0.05)


def test_long_prefill_chunked_path_matches():
    """The q-chunked attention path (Sq >= 8192) matches full attention."""
    from repro.models.layers import _sdpa
    q = jax.random.normal(jax.random.key(0), (1, 8192, 2, 16), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (1, 8192, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (1, 8192, 2, 16), jnp.float32)
    full = _sdpa(q, k, v, causal=True, q_chunk=None)
    chunked = _sdpa(q, k, v, causal=True, q_chunk=1024)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)
