"""End-to-end SubStrat strategy (paper §1.1 / §3.4): the three steps wire
together, fine-tune restricts to M''s family, and relative accuracy on a
learnable dataset stays high."""
import jax
import numpy as np
import pytest

from repro.automl.engine import AutoMLConfig, automl_fit
from repro.core.gen_dst import GenDSTConfig
from repro.core.substrat import SubStratConfig, substrat
from repro.core.baselines import ig_km_dst, mc_dst
from repro.data.tabular import DatasetSpec, make_dataset, train_test_split


@pytest.fixture(scope="module")
def dataset():
    spec = DatasetSpec("t", "test", 3000, 10, 2, frac_informative=0.6, seed=5)
    X, y = make_dataset(spec)
    return train_test_split(X, y, 0.25, seed=1)


SUB_CFG = SubStratConfig(
    # 2-island multi-start Gen-DST (DESIGN.md §5.5) — also covers the island
    # path end-to-end through the full 3-step strategy
    gen=GenDSTConfig(psi=6, phi=12, num_islands=2, migrate_every=3),
    sub_automl=AutoMLConfig(n_trials=8, rungs=(20, 60)),
    ft_automl=AutoMLConfig(n_trials=4, rungs=(60,)),
)


@pytest.fixture(scope="module")
def full_result(dataset):
    Xtr, ytr, Xte, yte = dataset
    return automl_fit(Xtr, ytr, config=AutoMLConfig(n_trials=8, rungs=(20, 60)),
                      X_test=Xte, y_test=yte)


@pytest.fixture(scope="module")
def sub_result(dataset):
    Xtr, ytr, Xte, yte = dataset
    return substrat(Xtr, ytr, key=jax.random.key(0), config=SUB_CFG,
                    X_test=Xte, y_test=yte)


def test_substrat_runs_all_phases(sub_result):
    for k in ("factorize_s", "gen_dst_s", "automl_sub_s", "fine_tune_s"):
        assert k in sub_result.times
    assert sub_result.total_time_s > 0


def test_substrat_restricts_family(sub_result):
    assert sub_result.final.spec.family == sub_result.intermediate.spec.family


def test_substrat_dst_size(sub_result, dataset):
    Xtr, *_ = dataset
    n_expected = int(round(len(Xtr) ** 0.5))
    assert sub_result.row_idx.shape == (n_expected,)
    assert len(sub_result.col_idx) >= 1


def test_substrat_relative_accuracy(sub_result, full_result):
    rel = sub_result.final.test_acc / max(full_result.test_acc, 1e-9)
    assert rel >= 0.90, f"relative accuracy {rel:.3f} too low"


def test_substrat_nf_variant(dataset):
    Xtr, ytr, Xte, yte = dataset
    import dataclasses
    cfg = dataclasses.replace(SUB_CFG, fine_tune=False)
    res = substrat(Xtr, ytr, key=jax.random.key(1), config=cfg,
                   X_test=Xte, y_test=yte)
    assert "fine_tune_s" not in res.times
    assert res.final.test_acc is not None


def test_substrat_with_baseline_dst(dataset):
    """Any baseline DST generator plugs into the same 3-step wrapper."""
    Xtr, ytr, Xte, yte = dataset
    for fn in (lambda k, c, n, m: mc_dst(k, c, n, m, budget=40, batch=20),
               ig_km_dst):
        res = substrat(Xtr, ytr, key=jax.random.key(2), config=SUB_CFG,
                       dst_fn=fn, X_test=Xte, y_test=yte)
        assert res.final.test_acc is not None
