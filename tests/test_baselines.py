"""The 10 baseline DST generators (paper §4.2): validity + sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import (
    mc_dst, mab_dst, greedy_seq_dst, greedy_mult_dst, km_dst,
    ig_rand_dst, ig_km_dst, information_gain, kmeans,
)
from repro.core.measures import factorize


@pytest.fixture(scope="module")
def coded():
    rng = np.random.default_rng(3)
    y = rng.integers(0, 2, 800)
    informative = y * 3 + rng.integers(0, 3, 800)      # strongly y-dependent
    noise = [rng.integers(0, 8, 800) for _ in range(4)]
    X = np.column_stack([informative] + noise).astype(float)
    return factorize(X, y.astype(float))


ALL_BASELINES = [
    ("mc", lambda k, c: mc_dst(k, c, 20, 3, budget=60, batch=20)),
    ("mab", lambda k, c: mab_dst(k, c, 20, 3, rounds=30)),
    ("greedy_seq", lambda k, c: greedy_seq_dst(k, c, 20, 3, pool=16)),
    ("greedy_mult", lambda k, c: greedy_mult_dst(k, c, 20, 3, pool=16)),
    ("km", lambda k, c: km_dst(k, c, 20, 3)),
    ("ig_rand", lambda k, c: ig_rand_dst(k, c, 20, 3)),
    ("ig_km", lambda k, c: ig_km_dst(k, c, 20, 3)),
]


@pytest.mark.parametrize("name,fn", ALL_BASELINES, ids=[n for n, _ in ALL_BASELINES])
def test_baseline_valid_dst(name, fn, coded):
    res = fn(jax.random.key(0), coded)
    assert res.row_idx.shape == (20,)
    assert (np.asarray(res.row_idx) >= 0).all()
    assert (np.asarray(res.row_idx) < coded.num_rows).all()
    assert bool(res.col_mask[coded.target_col])
    assert 2 <= int(res.col_mask.sum()) <= 3
    assert np.isfinite(float(res.fitness))


def test_mc_budget_improves(coded):
    small = mc_dst(jax.random.key(1), coded, 20, 3, budget=10, batch=10)
    big = mc_dst(jax.random.key(1), coded, 20, 3, budget=400, batch=50)
    assert float(big.fitness) >= float(small.fitness) - 1e-6


def test_information_gain_finds_informative_column(coded):
    ig = np.asarray(information_gain(coded.codes, coded.max_bins, coded.target_col))
    assert ig.argmax() == 0, f"IG should pick the y-correlated column, got {ig}"


def test_ig_dsts_select_informative(coded):
    res = ig_rand_dst(jax.random.key(2), coded, 20, 3)
    assert bool(res.col_mask[0]), "IG column selection must include informative col"


def test_kmeans_basics():
    rng = np.random.default_rng(0)
    pts = np.concatenate([rng.normal(-5, 0.3, (50, 2)), rng.normal(5, 0.3, (50, 2))])
    cent, nearest = kmeans(jax.random.key(0), jnp.asarray(pts, jnp.float32), 2, iters=10)
    assert cent.shape == (2, 2)
    assert nearest.shape == (2,)
    # the two representatives come from different clusters
    assert (pts[np.asarray(nearest)][:, 0] < 0).sum() == 1
