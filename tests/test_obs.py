"""Observability subsystem: spans, metrics, jit accounting (DESIGN.md §15).

The serving-tier tests reuse test_transport's deterministic chaos setup:
``DistributedScheduler`` over ``SimWorkerPool`` with a compiled FaultPlan,
so the retry-span and heartbeat-miss assertions have zero timing
dependence.
"""
import json

import numpy as np
import pytest

import jax

from harness.faultsim import FaultPlan
from repro.automl.engine import AutoMLConfig
from repro.core.plan import execute, plan
from repro.obs import jaxprof, trace
from repro.obs.metrics import MetricsRegistry
from repro.service import (
    DistributedScheduler, SimWorkerPool, SubStratServer, wire,
)
from repro.service.cache import DSTCache
from repro.service.scheduler import CohortMeta, Scheduler

PLAN = plan("gen_dst", n=24, m=4,
            sub_automl=AutoMLConfig(n_trials=4, rungs=(2, 4)),
            ft_automl=AutoMLConfig(n_trials=2, rungs=(2,)),
            psi=4, phi=10)


def _make(seed, N=48, d=6, c=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, d)).astype(np.float32)
    y = (np.arange(N) % c).astype(np.int64)
    return X, y


# ---------------------------------------------------------------------------
# spans: deterministic ids, nesting, rendering
# ---------------------------------------------------------------------------


def test_span_ids_are_deterministic_and_attempt_scoped():
    tid = trace.job_trace_id(7)
    assert tid == trace.job_trace_id(7)
    assert tid != trace.job_trace_id(8)
    a0 = trace.span_id(tid, "sub_automl/rung0", 0)
    assert a0 == trace.span_id(tid, "sub_automl/rung0", 0)
    # a retry is a *distinct* span of the same logical work
    assert a0 != trace.span_id(tid, "sub_automl/rung0", 1)
    assert a0 != trace.span_id(tid, "sub_automl/rung1", 0)


def test_span_contextvar_nesting_and_error_attr():
    sink = []
    with trace.span(sink, "t", "outer") as outer:
        with trace.span(sink, "t", "inner"):
            assert trace.current_span()["name"] == "inner"
        assert trace.current_span() is outer
    assert trace.current_span() is None
    inner, outer = sink          # children close (and append) first
    assert inner["name"] == "inner"
    assert inner["parent_id"] == outer["span_id"]
    assert outer["parent_id"] is None
    assert inner["t1"] >= inner["t0"]

    with pytest.raises(ValueError):
        with trace.span(sink, "t", "boom"):
            raise ValueError("x")
    assert sink[-1]["attrs"]["error"] is True
    assert trace.current_span() is None


def test_worker_parent_derivation_needs_no_id_exchange():
    """Both ends derive the same dispatch-span id from the wire ctx."""
    tid = trace.span_id("substrat-tasks", "0")
    ctx = trace.child_ctx(tid, "dispatch")
    front = trace.make_span(tid, "dispatch", 0.0, 1.0, attempt=2)
    remote_parent = trace.span_id(ctx["trace_id"], ctx["parent"], 2)
    assert remote_parent == front["span_id"]


def test_render_timeline_marks_retries_and_nesting():
    tid = "t"
    d0 = trace.make_span(tid, "dispatch", 0.0, 1.0, attempt=0,
                         attrs={"outcome": "lost", "worker": 0})
    d1 = trace.make_span(tid, "dispatch", 1.0, 3.0, attempt=1,
                         attrs={"outcome": "ok", "worker": 1})
    ev = trace.make_span(tid, "eval", 1.2, 2.8, attempt=1,
                         parent_id=d1["span_id"])
    out = trace.render_timeline([d0, d1, ev])
    lines = out.splitlines()
    assert len(lines) == 3
    assert "(retry #1)" in out
    assert "outcome=lost" in lines[0]
    assert lines[2].startswith("  eval (retry #1)")   # nested under d1


# ---------------------------------------------------------------------------
# metrics: exposition + bit-identical persistence
# ---------------------------------------------------------------------------


def test_metrics_exposition_and_dict():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests", labels=("mode",))
    g = reg.gauge("depth", "queue depth")
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    c.inc(mode="solo")
    c.inc(2, mode="merged")
    g.set(3.5)
    h.observe(0.05)
    h.observe(5.0)
    text = reg.render()
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{mode="solo"} 1' in text
    assert 'reqs_total{mode="merged"} 2' in text
    assert "depth 3.5" in text
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_count 2" in text
    assert reg.to_dict()["reqs_total"]["values"] == {"merged": 2, "solo": 1}
    with pytest.raises(ValueError):
        c.inc(wrong_label=1)
    with pytest.raises(ValueError):
        reg.gauge("reqs_total", "type clash")


def test_metrics_state_roundtrip_is_bit_identical():
    reg = MetricsRegistry()
    reg.counter("a_total", "a", labels=("k",)).inc(3, k="x")
    reg.histogram("h_seconds", "h", buckets=(0.5,)).observe(0.25)
    reg.gauge("g", "g").set(1.25)
    state = reg.state_dict()
    fresh = MetricsRegistry()
    fresh.load_state(json.loads(json.dumps(state)))   # survive JSON too
    assert fresh.state_dict() == state
    assert fresh.render() == reg.render()
    # restored families stay live
    fresh.counter("a_total", "a", labels=("k",)).inc(k="x")
    assert fresh.get("a_total").value(k="x") == 4


# ---------------------------------------------------------------------------
# jaxprof: tracing counters + FLOP accounting
# ---------------------------------------------------------------------------


def test_note_trace_counts_compiles_not_calls():
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        jaxprof.note_trace("test_obs.f")
        return x * 2

    snap = jaxprof.tracing_snapshot()
    f(jnp.ones((3,))).block_until_ready()
    assert jaxprof.new_tracings_since(snap) == {"test_obs.f": 1}
    snap2 = jaxprof.tracing_snapshot()
    f(jnp.zeros((3,))).block_until_ready()     # same shape: cached
    assert jaxprof.new_tracings_since(snap2) == {}
    f(jnp.ones((4,))).block_until_ready()      # new shape: re-trace
    assert jaxprof.new_tracings_since(snap2) == {"test_obs.f": 1}


def test_pack_flops_padded_vs_useful():
    uniform = [CohortMeta(shape=(64, 16, 8, 3), steps=(4, 4))]
    padded, useful = jaxprof.pack_flops(uniform)
    assert padded == useful > 0
    mixed = [CohortMeta(shape=(64, 16, 8, 3), steps=(4,)),
             CohortMeta(shape=(32, 8, 4, 2), steps=(2,))]
    padded, useful = jaxprof.pack_flops(mixed)
    assert padded > useful          # the small cohort pays the big shape
    # both trials priced at the maximal shape and step budget
    from repro.launch.flops import tabular_trial_flops
    assert padded == 2 * tabular_trial_flops(64, 16, 8, 3, 4)


def test_dispatch_hook_opt_in():
    seen = []
    jaxprof.set_dispatch_hook(lambda name, s, meta: seen.append((name, meta)))
    try:
        jaxprof.dispatch_event("rung_dispatch", 0.1, mode="solo")
    finally:
        jaxprof.set_dispatch_hook(None)
    jaxprof.dispatch_event("ignored", 0.1)
    assert seen == [("rung_dispatch", {"mode": "solo"})]


def test_prometheus_jaxprof_block_well_formed():
    text = jaxprof.render_prometheus()
    assert "# TYPE jax_jit_tracings_total counter" in text
    for line in text.splitlines():
        assert line.startswith(("#", "jax_")), line


# ---------------------------------------------------------------------------
# wire: trace-context header (v2)
# ---------------------------------------------------------------------------


def test_wire_trace_header_roundtrip():
    ctx = trace.child_ctx("abc123", "dispatch", attempt=1)
    blob = wire.dumps({"x": np.arange(3)}, kind="task", trace=ctx)
    assert wire.trace_of(blob) == ctx
    assert wire.kind_of(blob) == "task"
    np.testing.assert_array_equal(wire.loads(blob)["x"], np.arange(3))
    # absent by default — and absence is not an error
    assert wire.trace_of(wire.dumps({"x": 1})) is None


# ---------------------------------------------------------------------------
# serving tier: phase spans, poll() phase_times, snapshot persistence
# ---------------------------------------------------------------------------


def _run_one(sched):
    X, y = _make(0)
    jid = sched.submit(X, y, key=jax.random.key(1), plan=PLAN)
    sched.run()
    assert sched.jobs[jid].phase == "done"
    return jid


def test_job_spans_rebuild_the_times_ledger():
    sched = Scheduler(DSTCache())
    jid = _run_one(sched)
    job = sched.jobs[jid]
    assert job.trace_id == trace.job_trace_id(jid)
    assert all(s["trace_id"] == job.trace_id for s in job.spans)
    by_name = {}
    for s in job.spans:
        by_name.setdefault(s["name"], 0.0)
        by_name[s["name"]] += s["attrs"].get("seconds",
                                             s["t1"] - s["t0"])
    # spans cover every times key the pre-span scheduler recorded
    for name, key in (("factorize", "factorize_s"),
                      ("gen_dst", "gen_dst_s")):
        assert job.times[key] == pytest.approx(by_name[name])
    rung_total = sum(v for n, v in by_name.items()
                     if n.startswith("sub_automl/"))
    assert job.times["automl_sub_s"] == pytest.approx(rung_total)


def test_poll_reports_phase_times():
    srv = SubStratServer()
    jid = _run_one(srv.scheduler)
    st = srv.poll(jid)
    assert set(st.phase_times) == {"factorize", "gen_dst",
                                   "sub_automl", "fine_tune"}
    assert st.phase_times["gen_dst"] > 0
    assert st.phase_times["sub_automl"] > 0
    assert st.phase_times["factorize"] == \
        pytest.approx(st.times["factorize_s"])


def test_snapshot_restores_metrics_and_spans_bit_identically():
    sched = Scheduler(DSTCache())
    jid = _run_one(sched)
    blob = sched.snapshot()
    fresh = Scheduler(DSTCache())
    fresh.load_snapshot(blob)
    assert fresh.jobs[jid].spans == sched.jobs[jid].spans
    assert fresh.jobs[jid].trace_id == sched.jobs[jid].trace_id
    assert fresh.metrics.state_dict() == sched.metrics.state_dict()
    assert fresh.metrics.render() == sched.metrics.render()
    # the restored registry is live: finishing another job keeps counting
    before = fresh.metrics.get("jobs_finished_total").value(phase="done")
    _run_one(fresh)
    after = fresh.metrics.get("jobs_finished_total").value(phase="done")
    assert after == before + 1


def test_scheduler_counts_dispatches_and_cache_hits():
    sched = Scheduler(DSTCache())
    X, y = _make(0)
    a = sched.submit(X, y, key=jax.random.key(1), plan=PLAN)
    b = sched.submit(X, y, key=jax.random.key(2), plan=PLAN)  # repeat
    sched.run()
    m = sched.stats()["metrics"]
    assert m["cache_hits_total"]["value"] >= 1
    assert sum(m["dispatches_total"]["values"].values()) >= 1
    assert m["jobs_finished_total"]["values"]["done"] == 2
    assert sched.jobs[a].phase == sched.jobs[b].phase == "done"


# ---------------------------------------------------------------------------
# chaos: the killed task's re-dispatch is a visible retry span
# ---------------------------------------------------------------------------


def test_killed_task_shows_as_retry_span_with_children():
    pool = SimWorkerPool(2, fault_events=FaultPlan.kill(0, 0).compile())
    sched = DistributedScheduler(pool, cache=DSTCache())
    X, y = _make(0)
    jid = sched.submit(X, y, key=jax.random.key(1), plan=PLAN)
    sched.run()
    assert sched.jobs[jid].phase == "done"
    assert sched.metrics.get("heartbeat_misses_total").value() >= 1

    spans = sched.jobs[jid].spans
    dispatches = [s for s in spans if s["name"] == "dispatch"]
    lost = [s for s in dispatches if s["attrs"].get("outcome") == "lost"]
    retries = [s for s in dispatches if s["attempt"] > 0]
    assert lost and retries, "kill must leave a lost span and a retry span"
    assert all(s["attrs"]["outcome"] == "ok" for s in retries)
    # distinct ids: the retry is its own span of the same logical dispatch
    assert {s["span_id"] for s in lost}.isdisjoint(
        {s["span_id"] for s in retries})
    retry = retries[0]
    kids = {s["name"] for s in spans
            if s.get("parent_id") == retry["span_id"]}
    assert {"queue_wait", "eval"} <= kids
    # the rendered timeline shows it all without errors
    out = trace.render_timeline(spans)
    assert "(retry #1)" in out and "outcome=lost" in out


def test_sim_pool_spans_fold_into_job_timeline():
    sched = DistributedScheduler(SimWorkerPool(2), cache=DSTCache())
    jid = _run_one(sched)
    spans = sched.jobs[jid].spans
    names = {s["name"] for s in spans}
    assert {"dispatch", "queue_wait", "deserialize", "eval",
            "serialize"} <= names
    assert all(s["trace_id"] == sched.jobs[jid].trace_id for s in spans)
    # every worker-side span hangs off a front-end dispatch span
    ids = {s["span_id"] for s in spans}
    for s in spans:
        if s["name"] in ("deserialize", "eval", "serialize"):
            assert s["parent_id"] in ids


# ---------------------------------------------------------------------------
# one-shot path: execute(trace_sink=...) mirrors the times ledger
# ---------------------------------------------------------------------------


def test_execute_trace_sink_matches_times():
    X, y = _make(3)
    sink = []
    res = execute(PLAN, X, y, key=jax.random.key(0), trace_sink=sink)
    names = [s["name"] for s in sink]
    assert names == ["factorize", "gen_dst", "sub_automl", "fine_tune"]
    for s, key in zip(sink, ("factorize_s", "gen_dst_s",
                             "automl_sub_s", "fine_tune_s")):
        assert res.times[key] == pytest.approx(s["t1"] - s["t0"], abs=0.05)
    assert trace.render_timeline(sink)
