"""Gradient compression: quantization bounds + error feedback; the
shard_map compressed_psum is exercised in the multi-device subprocess test
(test_distributed_subprocess.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env — deterministic fallback, same API subset
    from _hyp_fallback import given, settings, strategies as st

from repro.distributed.compression import (
    ErrorFeedback, dequantize_int8, ef_compress, quantize_int8,
)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1000), st.floats(0.01, 100.0))
def test_quantize_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, (256,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6, "error bounded by half a step"


def test_error_feedback_preserves_signal():
    """Accumulated EF-compressed updates track the true gradient sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (128,)), jnp.float32) * 1e-3
    ef = ErrorFeedback(jnp.zeros((128,)))
    total = jnp.zeros((128,))
    for _ in range(50):
        q, s, ef = ef_compress(g_true, ef)
        total = total + dequantize_int8(q, s)
    # mean of transmitted == 50 * g_true up to one quantization step
    np.testing.assert_allclose(np.asarray(total), np.asarray(g_true) * 50,
                               atol=float(jnp.abs(g_true).max()) * 2)


def test_zero_gradient_stays_zero():
    q, s = quantize_int8(jnp.zeros((16,)))
    assert (np.asarray(q) == 0).all()
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s)), 0.0)
