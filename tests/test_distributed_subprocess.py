"""Multi-device behaviours that need >1 device: run in a subprocess with
xla_force_host_platform_device_count (must be set before jax init, hence the
separate process)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str, devices: int = 8, timeout: int = 540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharding_rules_on_8dev_mesh():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.distributed.sharding import rules_for, param_specs, batch_specs, cache_specs
        from repro.configs import ARCHS
        from repro.models import lm

        mesh = make_mesh((2, 4), ("data", "model"))
        arch = ARCHS["qwen3-8b"]
        cfg = arch.smoke
        params = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.key(0))
        rules = rules_for(cfg, mesh, "train")
        specs = param_specs(params, cfg, mesh, rules)
        # attention q: (L, D, H, hd): heads sharded over model (4 heads / 4)
        qspec = specs["layers"]["attn"]["q"]
        assert qspec[2] == "model", qspec
        bspecs = batch_specs({"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}, mesh, rules)
        assert bspecs["tokens"][0] == "data", bspecs
        cache = jax.eval_shape(lambda: lm.init_cache(cfg, 8, 32))
        cspecs = cache_specs(cache, cfg, mesh, rules)
        print("OK", qspec, bspecs["tokens"], cspecs.k)
    """)
    assert "OK" in out


def test_compressed_psum_matches_plain():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.distributed.compression import compressed_psum

        shard_map = getattr(jax, "shard_map", None)  # jax<0.6 compat
        if shard_map is None:
            from jax.experimental.shard_map import shard_map

        mesh = make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.key(0), (8, 128), jnp.float32)

        @jax.jit
        def plain(x):
            return shard_map(
                lambda xs: jax.lax.psum(xs[0], "data")[None],
                mesh=mesh, in_specs=P("data"), out_specs=P("data"))(x)

        @jax.jit
        def comp(x):
            return shard_map(
                lambda xs: compressed_psum(xs[0], "data")[None],
                mesh=mesh, in_specs=P("data"), out_specs=P("data"))(x)

        p = np.asarray(plain(x))
        c = np.asarray(comp(x))
        scale = np.abs(p).max()
        err = np.abs(p - c).max() / scale
        assert err < 0.05, f"relative err {err}"
        print("OK compressed_psum rel err", err)
    """)
    assert "OK" in out


def test_small_mesh_dryrun_train_and_decode():
    """End-to-end mini dry-run: smoke configs, (2,4) mesh, train + decode."""
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_costs import analyze_hlo
        from repro.distributed.sharding import (rules_for, param_specs,
            opt_state_specs, batch_specs, cache_specs, tree_shardings)
        from repro.configs import ARCHS, input_specs, decode_operand_specs
        from repro.models.config import ShapeSpec
        from repro.models import lm
        from repro.train.optimizer import make_optimizer, warmup_cosine
        from repro.train.train_step import TrainState, make_train_step, make_serve_step

        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeSpec("mini_train", 64, 8, "train")
        for arch_id in ("qwen3-8b", "qwen2-moe-a2.7b", "mamba2-130m"):
            cfg = dataclasses.replace(ARCHS[arch_id].smoke, remat=True)
            opt = make_optimizer("adamw", warmup_cosine(1e-3))
            state = jax.eval_shape(
                lambda k: TrainState(jnp.zeros((), jnp.int32),
                                     lm.init_params(k, cfg),
                                     opt.init(lm.init_params(k, cfg))),
                jax.random.key(0))
            rules = rules_for(cfg, mesh, "train")
            pspecs = param_specs(state.params, cfg, mesh, rules)
            ospecs = opt_state_specs(state.opt_state, pspecs, state.params, mesh)
            sspecs = TrainState(P(), pspecs, ospecs)
            batch = input_specs(cfg, shape)
            bspecs = batch_specs(batch, mesh, rules)
            step = make_train_step(cfg, opt, accum_steps=2)
            with mesh:
                lowered = jax.jit(step,
                    in_shardings=(tree_shardings(sspecs, mesh), tree_shardings(bspecs, mesh)),
                    out_shardings=(tree_shardings(sspecs, mesh), None)
                ).lower(state, batch)
                compiled = lowered.compile()
            cost = analyze_hlo(compiled.as_text())
            assert cost.flops > 0 and cost.bytes > 0
            print("OK train", arch_id, f"flops={cost.flops:.2e}")

        # decode cell for the dense smoke config
        cfg = ARCHS["qwen3-8b"].smoke
        dshape = ShapeSpec("mini_decode", 64, 8, "decode")
        cache, token, pos, pos_ref = decode_operand_specs(cfg, dshape)
        params = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.key(0))
        params = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                              if jnp.issubdtype(s.dtype, jnp.floating) else s, params)
        rules = rules_for(cfg, mesh, "decode")
        pspecs = param_specs(params, cfg, mesh, rules)
        cspecs = cache_specs(cache, cfg, mesh, rules)
        step = make_serve_step(cfg, "decode")
        with mesh:
            compiled = jax.jit(step, in_shardings=(
                tree_shardings(pspecs, mesh), tree_shardings(cspecs, mesh),
                NamedSharding(mesh, P("data")), NamedSharding(mesh, P()))
            ).lower(params, cache, token, pos).compile()
        cost = analyze_hlo(compiled.as_text())
        assert cost.flops > 0
        print("OK decode qwen3-smoke")
    """)
    assert out.count("OK") == 4
