"""Train step + optimizers: loss decreases, grad-accum equivalence,
adafactor state factoring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.train.optimizer import adafactor, adamw, make_optimizer, warmup_cosine
from repro.train.train_step import (
    TrainState, init_train_state, make_train_step, xent_loss,
)

CFG = ModelConfig("t", "dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                  head_dim=8, d_ff=64, vocab_size=64, remat=False,
                  dtype="float32")


def _batch(key, b=8, s=16):
    toks = jax.random.randint(key, (b, s + 1), 0, 64)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_xent_loss_masking():
    logits = jnp.zeros((2, 4, 8))
    labels = jnp.array([[1, 2, -1, -1], [3, -1, -1, -1]])
    loss = xent_loss(logits, labels, z_loss=0.0)
    assert float(loss) == pytest.approx(np.log(8), rel=1e-5)


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_decreases(opt_name):
    opt = make_optimizer(opt_name, warmup_cosine(3e-3, warmup=5, total=100))
    state = init_train_state(jax.random.key(0), CFG, opt)
    step = jax.jit(make_train_step(CFG, opt, accum_steps=1))
    batch = _batch(jax.random.key(1))
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, f"no learning: {losses[0]} -> {losses[-1]}"


def test_grad_accum_equivalence():
    """Microbatch-accumulated gradients equal the full-batch gradient.

    (Compared at the gradient level: Adam at step 0 behaves like sign-SGD,
    so post-optimizer params amplify float noise into ±lr flips.)"""
    from repro.models import lm

    batch = _batch(jax.random.key(2), b=8)
    params = lm.init_params(jax.random.key(0), CFG)

    def loss_fn(p, mb):
        return xent_loss(lm.forward(p, mb, CFG), mb["labels"])

    g_full = jax.grad(loss_fn)(params, batch)
    mbs = jax.tree.map(lambda a: a.reshape((4, 2) + a.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, params)
    for i in range(4):
        mb = jax.tree.map(lambda a: a[i], mbs)
        g = jax.grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b / 4.0, g_acc, g)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)
    # and the step-level loss agrees between accum settings
    opt = adamw(lambda s: 1e-2)
    s0 = init_train_state(jax.random.key(0), CFG, opt)
    _, m1 = jax.jit(make_train_step(CFG, opt, accum_steps=1))(s0, batch)
    _, m4 = jax.jit(make_train_step(CFG, opt, accum_steps=4))(s0, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 1e-3)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    leaves = jax.tree.leaves(params)
    # matrix leaf: factored vr/vc; vector leaf: full v
    sizes = sum(np.prod(v[k].shape) for v in st["v"] for k in v)
    full = sum(np.prod(l.shape) for l in leaves)
    assert sizes < full, "adafactor state must be smaller than params"


def test_adafactor_with_momentum():
    opt = adafactor(lambda s: 1e-3, beta1=0.9)
    params = {"w": jnp.ones((8, 8))}
    st = opt.init(params)
    assert "m" in st
    g = {"w": jnp.ones((8, 8))}
    p2, st2 = opt.update(g, st, params, jnp.int32(0))
    assert float(jnp.abs(p2["w"] - params["w"]).max()) > 0


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1e-3, warmup=10, total=100, floor=0.1)
    assert float(lr(jnp.int32(0))) < 2e-4
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(lr(jnp.int32(100))) == pytest.approx(1e-4, rel=0.05)


def test_bf16_param_training():
    import dataclasses
    cfg = dataclasses.replace(CFG, param_dtype="bfloat16")
    opt = adafactor(lambda s: 1e-2)
    state = init_train_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, accum_steps=2))
    state, m = step(state, _batch(jax.random.key(3)))
    assert np.isfinite(float(m["loss"]))
    assert state.params["embed"].dtype == jnp.bfloat16
