"""Fault tolerance: restart-equivalence, shard reassignment, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env — deterministic fallback, same API subset
    from _hyp_fallback import given, settings, strategies as st

from repro.distributed.fault import FaultTolerantLoop, Heartbeat, assign_shards


# ---------------------------------------------------------------------------
# assign_shards
# ---------------------------------------------------------------------------


def test_assign_all_alive():
    a = assign_shards(8, list(range(4)), 4)
    assert a == {s: s % 4 for s in range(8)}


def test_assign_dead_host_rebalanced():
    a = assign_shards(8, [0, 2, 3], 4)
    assert all(h in (0, 2, 3) for h in a.values())
    # surviving hosts keep their home shards
    for s in range(8):
        if s % 4 != 1:
            assert a[s] == s % 4


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 32), st.integers(1, 8), st.integers(0, 2 ** 8 - 1))
def test_assign_shards_properties(n_shards, n_hosts, alive_bits):
    alive = [h for h in range(n_hosts) if alive_bits & (1 << h)]
    if not alive:
        alive = [0]
    a = assign_shards(n_shards, alive, n_hosts)
    assert set(a.keys()) == set(range(n_shards))       # every shard assigned
    assert all(h in alive for h in a.values())          # only to alive hosts
    # balance: no alive host holds more than ceil(n/alive)+floor share slack
    from collections import Counter
    counts = Counter(a.values())
    assert max(counts.values()) <= int(np.ceil(n_shards / len(alive))) + \
        n_shards // max(len(alive), 1)


# ---------------------------------------------------------------------------
# heartbeat / stragglers
# ---------------------------------------------------------------------------


def test_heartbeat_stragglers():
    hb = Heartbeat(n_hosts=4, straggler_factor=3.0)
    for h in range(3):
        hb.beat(h, 1.0)
    hb.beat(3, 10.0)
    assert hb.stragglers() == [3]


# ---------------------------------------------------------------------------
# restart equivalence
# ---------------------------------------------------------------------------


def _make_loop(tmp_path):
    def step_fn(state, batch):
        new = {"x": state["x"] * 0.9 + batch.sum(), "n": state["n"] + 1}
        return new, {"x": new["x"]}

    def batch_fn(step):
        rng = np.random.default_rng(step)
        return jnp.asarray(rng.normal(0, 1, (4,)), jnp.float32)

    return FaultTolerantLoop(step_fn, batch_fn, tmp_path, ckpt_every=3)


def test_restart_reproduces_uninterrupted_run(tmp_path):
    init = {"x": jnp.float32(1.0), "n": jnp.int32(0)}
    golden, _ = _make_loop(tmp_path / "golden").run(init, 10)

    loop = _make_loop(tmp_path / "crashy")
    with pytest.raises(RuntimeError, match="simulated node failure"):
        loop.run(init, 10, simulate_failure_at=7)
    resumed, _ = _make_loop(tmp_path / "crashy").run(init, 10)

    assert int(resumed["n"]) == int(golden["n"]) == 10
    np.testing.assert_allclose(float(resumed["x"]), float(golden["x"]), rtol=1e-6)


def test_restart_skips_completed_steps(tmp_path):
    init = {"x": jnp.float32(1.0), "n": jnp.int32(0)}
    loop = _make_loop(tmp_path)
    loop.run(init, 6)
    calls = []
    loop2 = _make_loop(tmp_path)
    orig = loop2.step_fn

    def counting(state, batch):
        calls.append(1)
        return orig(state, batch)

    loop2.step_fn = counting
    loop2.run(init, 10)
    assert len(calls) == 4, "only steps 6..9 re-run after restore"
