"""Fault tolerance: restart-equivalence, shard reassignment, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env — deterministic fallback, same API subset
    from _hyp_fallback import given, settings, strategies as st

from repro.distributed.fault import FaultTolerantLoop, Heartbeat, assign_shards


# ---------------------------------------------------------------------------
# assign_shards
# ---------------------------------------------------------------------------


def test_assign_all_alive():
    a = assign_shards(8, list(range(4)), 4)
    assert a == {s: s % 4 for s in range(8)}


def test_assign_dead_host_rebalanced():
    a = assign_shards(8, [0, 2, 3], 4)
    assert all(h in (0, 2, 3) for h in a.values())
    # surviving hosts keep their home shards
    for s in range(8):
        if s % 4 != 1:
            assert a[s] == s % 4


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 32), st.integers(1, 8), st.integers(0, 2 ** 8 - 1))
def test_assign_shards_properties(n_shards, n_hosts, alive_bits):
    alive = [h for h in range(n_hosts) if alive_bits & (1 << h)]
    if not alive:
        alive = [0]
    a = assign_shards(n_shards, alive, n_hosts)
    assert set(a.keys()) == set(range(n_shards))       # every shard assigned
    assert all(h in alive for h in a.values())          # only to alive hosts
    # balance: no alive host holds more than ceil(n/alive)+floor share slack
    from collections import Counter
    counts = Counter(a.values())
    assert max(counts.values()) <= int(np.ceil(n_shards / len(alive))) + \
        n_shards // max(len(alive), 1)


def _alive_from_bits(n_hosts, alive_bits):
    alive = [h for h in range(n_hosts) if alive_bits & (1 << h)]
    return alive or [0]


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 48), st.integers(1, 8), st.integers(0, 2 ** 8 - 1))
def test_survivors_keep_home_shards(n_shards, n_hosts, alive_bits):
    """A host that stays alive never loses a shard it already owned —
    re-dispatch after a fault only moves the dead host's work."""
    alive = _alive_from_bits(n_hosts, alive_bits)
    a = assign_shards(n_shards, alive, n_hosts)
    for s in range(n_shards):
        if s % n_hosts in alive:
            assert a[s] == s % n_hosts


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 48), st.integers(1, 8), st.integers(0, 2 ** 8 - 1))
def test_orphan_spread_within_one_of_balanced(n_shards, n_hosts, alive_bits):
    """Orphans go least-loaded-first, so total load stays within one shard
    of perfectly balanced — no survivor absorbs a dead host's whole queue."""
    from collections import Counter
    alive = _alive_from_bits(n_hosts, alive_bits)
    a = assign_shards(n_shards, alive, n_hosts)
    counts = Counter(a.values())
    loads = [counts.get(h, 0) for h in alive]
    assert max(loads) - min(loads) <= 1


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 48), st.integers(1, 8), st.integers(0, 2 ** 8 - 1),
       st.integers(0, 10 ** 6))
def test_assignment_identical_across_hosts(n_shards, n_hosts, alive_bits,
                                           shuffle_seed):
    """Every host computes the same map from the same alive-set — argument
    order and repetition must not matter (no coordinator anywhere)."""
    import random as _random
    alive = _alive_from_bits(n_hosts, alive_bits)
    reference = assign_shards(n_shards, alive, n_hosts)
    shuffled = list(alive)
    _random.Random(shuffle_seed).shuffle(shuffled)
    assert assign_shards(n_shards, shuffled, n_hosts) == reference
    assert assign_shards(n_shards, shuffled + shuffled, n_hosts) == reference


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 48), st.integers(2, 8), st.integers(0, 7))
def test_dead_then_revived_sequence_deterministic(n_shards, n_hosts,
                                                  dead_host):
    """kill → recover → revive replays to the same assignments: the map is
    a pure function of the alive-set, so a fault-and-heal sequence is
    reproducible and revival restores the original placement exactly."""
    dead_host = dead_host % n_hosts
    full = list(range(n_hosts))
    degraded = [h for h in full if h != dead_host] or [0]
    before = assign_shards(n_shards, full, n_hosts)
    during1 = assign_shards(n_shards, degraded, n_hosts)
    during2 = assign_shards(n_shards, degraded, n_hosts)
    after = assign_shards(n_shards, full, n_hosts)
    assert during1 == during2            # the degraded map is stable
    assert after == before               # revival restores home placement
    # and the degraded map reassigned exactly the dead host's shards
    moved = {s for s in range(n_shards) if during1[s] != before[s]}
    assert moved == {s for s in range(n_shards)
                     if before[s] == dead_host and n_hosts > 1}


# ---------------------------------------------------------------------------
# heartbeat / stragglers
# ---------------------------------------------------------------------------


def test_heartbeat_stragglers():
    hb = Heartbeat(n_hosts=4, straggler_factor=3.0)
    for h in range(3):
        hb.beat(h, 1.0)
    hb.beat(3, 10.0)
    assert hb.stragglers() == [3]


# ---------------------------------------------------------------------------
# restart equivalence
# ---------------------------------------------------------------------------


def _make_loop(tmp_path):
    def step_fn(state, batch):
        new = {"x": state["x"] * 0.9 + batch.sum(), "n": state["n"] + 1}
        return new, {"x": new["x"]}

    def batch_fn(step):
        rng = np.random.default_rng(step)
        return jnp.asarray(rng.normal(0, 1, (4,)), jnp.float32)

    return FaultTolerantLoop(step_fn, batch_fn, tmp_path, ckpt_every=3)


def test_restart_reproduces_uninterrupted_run(tmp_path):
    init = {"x": jnp.float32(1.0), "n": jnp.int32(0)}
    golden, _ = _make_loop(tmp_path / "golden").run(init, 10)

    loop = _make_loop(tmp_path / "crashy")
    with pytest.raises(RuntimeError, match="simulated node failure"):
        loop.run(init, 10, simulate_failure_at=7)
    resumed, _ = _make_loop(tmp_path / "crashy").run(init, 10)

    assert int(resumed["n"]) == int(golden["n"]) == 10
    np.testing.assert_allclose(float(resumed["x"]), float(golden["x"]), rtol=1e-6)


def test_restart_skips_completed_steps(tmp_path):
    init = {"x": jnp.float32(1.0), "n": jnp.int32(0)}
    loop = _make_loop(tmp_path)
    loop.run(init, 6)
    calls = []
    loop2 = _make_loop(tmp_path)
    orig = loop2.step_fn

    def counting(state, batch):
        calls.append(1)
        return orig(state, batch)

    loop2.step_fn = counting
    loop2.run(init, 10)
    assert len(calls) == 4, "only steps 6..9 re-run after restore"
