"""Batched population engine (DESIGN.md §10.3/§10.4): loop-vs-batched parity
and on-device successive-halving promotion."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.automl.engine import AutoMLConfig, automl_fit, sh_promote


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    N = 500
    y = rng.integers(0, 2, N)
    X = np.column_stack([
        y * 2.0 + rng.normal(0, 0.5, N),
        -y * 1.5 + rng.normal(0, 0.5, N),
        rng.normal(0, 1, N),
        rng.normal(0, 1, N),
        y * 0.5 + rng.normal(0, 1.0, N),
    ]).astype(np.float32)
    return X, y


CFG = dict(n_trials=12, rungs=(15, 40), seed=3)


def test_backend_parity_same_winner(data):
    """Same seed => same winning PipelineSpec and (near-)identical val accs.

    Both backends derive per-trial keys from (seed, trial_id, rung) and the
    batched path's zero-padding is gradient-inert, so the per-trial training
    trajectories coincide (DESIGN.md §10.4)."""
    X, y = data
    r_loop = automl_fit(X, y, config=AutoMLConfig(**CFG, backend="loop"))
    r_bat = automl_fit(X, y, config=AutoMLConfig(**CFG, backend="batched"))
    assert r_loop.spec == r_bat.spec
    assert r_loop.val_acc == pytest.approx(r_bat.val_acc, abs=1e-6)
    assert r_loop.n_trials == r_bat.n_trials
    # the full trial logs line up: same cohorts in the same order, and every
    # trial's validation accuracy matches within float tolerance
    assert [s for s, _ in r_loop.trials] == [s for s, _ in r_bat.trials]
    np.testing.assert_allclose(
        [v for _, v in r_loop.trials], [v for _, v in r_bat.trials], atol=1e-6)


def test_backend_parity_restricted(data):
    """Parity holds on the fine-tune-shaped restricted pass too."""
    X, y = data
    cfg = dict(n_trials=8, rungs=(30,), seed=1)
    r_loop = automl_fit(X, y, config=AutoMLConfig(**cfg, backend="loop"),
                        restrict_family="mlp")
    r_bat = automl_fit(X, y, config=AutoMLConfig(**cfg, backend="batched"),
                       restrict_family="mlp")
    assert r_loop.spec == r_bat.spec
    assert all(s.family == "mlp" for s, _ in r_bat.trials)
    np.testing.assert_allclose(
        [v for _, v in r_loop.trials], [v for _, v in r_bat.trials], atol=1e-6)


def test_batched_multiclass():
    rng = np.random.default_rng(1)
    N = 400
    y = rng.integers(0, 3, N)
    X = np.column_stack([(y == k) * 2.0 + rng.normal(0, 0.4, N) for k in range(3)])
    res = automl_fit(X.astype(np.float32), y,
                     config=AutoMLConfig(n_trials=6, rungs=(30,), backend="batched"))
    assert res.val_acc > 0.8
    assert res.backend == "batched"


def test_batched_result_params_usable(data):
    """Unpadded winner params drive apply_pipeline/accuracy exactly like the
    sequential path (needed by substrat's test-accuracy evaluation)."""
    X, y = data
    res = automl_fit(X[:400], y[:400], config=AutoMLConfig(**CFG, backend="batched"),
                     X_test=X[400:], y_test=y[400:])
    assert res.test_acc is not None and res.test_acc > 0.7


def test_unknown_backend_raises(data):
    X, y = data
    with pytest.raises(ValueError):
        automl_fit(X, y, config=AutoMLConfig(backend="nope"))


# ---------------------------------------------------------------------------
# successive-halving promotion on a fixed synthetic trial matrix
# ---------------------------------------------------------------------------


def test_sh_promote_topk_mask():
    vacc = jnp.asarray([0.50, 0.90, 0.70, 0.20, 0.80, 0.60])
    mask = np.asarray(sh_promote(vacc, keep_frac=0.34))
    # ceil(6 * 0.34) = 3 survivors: the three highest accuracies
    assert mask.tolist() == [False, True, True, False, True, False]


def test_sh_promote_tie_breaks_to_lower_index():
    vacc = jnp.asarray([0.70, 0.90, 0.90, 0.90, 0.10])
    mask = np.asarray(sh_promote(vacc, keep_frac=0.4))
    # keep 2: both winners come from the tied 0.90 group, lower indices first
    assert mask.tolist() == [False, True, True, False, False]


def test_sh_promote_keeps_at_least_one():
    mask = np.asarray(sh_promote(jnp.asarray([0.2, 0.1]), keep_frac=0.01))
    assert mask.sum() == 1 and bool(mask[0])


def test_sh_promote_matrix_rungs():
    """Fixed synthetic trial matrix: promotion cascades 9 -> 4 -> 1."""
    vacc0 = jnp.asarray([0.1, 0.9, 0.3, 0.8, 0.2, 0.7, 0.4, 0.6, 0.5])
    alive = np.flatnonzero(np.asarray(sh_promote(vacc0, 0.34)))
    assert alive.tolist() == [1, 3, 5, 7]            # ceil(9*0.34)=4, pop. order
    vacc1 = jnp.asarray([0.75, 0.95, 0.85, 0.65])    # rung-2 accs of survivors
    alive2 = alive[np.flatnonzero(np.asarray(sh_promote(vacc1, 0.25)))]
    assert alive2.tolist() == [3]
