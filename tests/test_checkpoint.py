"""Checkpointing: roundtrip, atomicity, corruption fallback, async, elastic."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import (
    CheckpointManager, latest_step, restore_latest, restore_resharded,
    save_checkpoint,
)


@pytest.fixture
def state():
    return {
        "step": jnp.int32(7),
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": [jnp.zeros((3, 4)), {"v": jnp.full((2,), 5.0)}],
    }


def test_roundtrip(tmp_path, state):
    save_checkpoint(tmp_path, 10, state)
    restored, step = restore_latest(tmp_path, state)
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_wins(tmp_path, state):
    save_checkpoint(tmp_path, 1, state)
    bumped = jax.tree.map(lambda a: a + 1, state)
    save_checkpoint(tmp_path, 2, bumped)
    restored, step = restore_latest(tmp_path, state)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(bumped["params"]["w"]))


def test_uncommitted_checkpoint_ignored(tmp_path, state):
    save_checkpoint(tmp_path, 1, state)
    fake = tmp_path / "step_00000005"
    fake.mkdir()
    (fake / "manifest.json").write_text("{}")   # no COMMIT
    assert latest_step(tmp_path) == 1
    _, step = restore_latest(tmp_path, state)
    assert step == 1


def test_corruption_falls_back(tmp_path, state):
    save_checkpoint(tmp_path, 1, state)
    save_checkpoint(tmp_path, 2, state)
    # corrupt step 2's first leaf
    leaf = tmp_path / "step_00000002" / "leaf_0.npy"
    leaf.write_bytes(b"garbage" + leaf.read_bytes()[7:])
    restored, step = restore_latest(tmp_path, state)
    assert step == 1, "must fall back to the intact checkpoint"


def test_retention(tmp_path, state):
    for s in range(6):
        save_checkpoint(tmp_path, s, state, keep=3)
    kept = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_????????"))
    assert kept == [3, 4, 5]


def test_async_manager(tmp_path, state):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(3, state)
    mgr.wait()
    assert latest_step(tmp_path) == 3
    restored, _ = restore_latest(tmp_path, state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_resharded_restore(tmp_path, state):
    """Elastic rescale: restore onto (trivially different) shardings."""
    save_checkpoint(tmp_path, 4, state)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda a: NamedSharding(mesh, P()), state)
    restored, step = restore_resharded(tmp_path, state, sh)
    assert step == 4
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())
