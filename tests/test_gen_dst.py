"""Gen-DST genetic algorithm: invariants + convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gen_dst import (
    GenDSTConfig, default_dst_size, gen_dst, gen_dst_batch, random_dst,
    _init_population, _mutate, _crossover, _crossover_splits, _select,
)
from repro.core.measures import factorize, subset_entropy


@pytest.fixture(scope="module")
def coded():
    rng = np.random.default_rng(0)
    X = np.column_stack([rng.integers(0, k, 1500) for k in (3, 5, 17, 2, 40, 7, 200)]).astype(float)
    y = rng.integers(0, 2, 1500).astype(float)
    return factorize(X, y)


CFG = GenDSTConfig(psi=8, phi=16)


def test_default_dst_size():
    assert default_dst_size(10000, 20) == (100, 5)
    assert default_dst_size(4, 3) == (2, 2)


def test_gen_dst_invariants(coded):
    n, m = 30, 3
    res = gen_dst(jax.random.key(0), coded, n, m, CFG)
    assert res.row_idx.shape == (n,)
    assert int(res.col_mask.sum()) == m
    assert bool(res.col_mask[coded.target_col]), "target column must be in DST"
    assert (np.asarray(res.row_idx) >= 0).all()
    assert (np.asarray(res.row_idx) < coded.num_rows).all()
    assert res.history.shape == (CFG.psi,)


def test_gen_dst_monotone_best(coded):
    res = gen_dst(jax.random.key(1), coded, 30, 3, CFG)
    h = np.asarray(res.history)
    assert (np.diff(h) >= -1e-6).all(), "best-so-far fitness must be monotone"
    assert float(res.fitness) >= h[0] - 1e-6


def test_gen_dst_beats_random(coded):
    res = gen_dst(jax.random.key(2), coded, 30, 3, CFG)
    ga_loss = -float(res.fitness)
    rand_losses = []
    for s in range(5):
        rd = random_dst(jax.random.key(100 + s), coded, 30, 3)
        f = float(subset_entropy(coded.codes, rd.row_idx, rd.col_mask, coded.max_bins))
        rand_losses.append(abs(f - float(res.f_ref)))
    assert ga_loss <= np.mean(rand_losses) + 1e-9, \
        f"GA loss {ga_loss} worse than mean random {np.mean(rand_losses)}"


def test_gen_dst_fitness_is_true_loss(coded):
    res = gen_dst(jax.random.key(3), coded, 25, 3, CFG)
    f_d = float(subset_entropy(coded.codes, res.row_idx, res.col_mask, coded.max_bins))
    assert abs(abs(f_d - float(res.f_ref)) - (-float(res.fitness))) < 1e-5


def test_operators_preserve_genome_shape(coded):
    N, M = coded.codes.shape
    n, m, phi = 12, 3, 8
    key = jax.random.key(0)
    rows, cols = _init_population(key, N, M, n, m, phi, coded.target_col)
    assert rows.shape == (phi, n) and cols.shape == (phi, M)
    assert (cols.sum(axis=1) == m).all()
    assert cols[:, coded.target_col].all()

    rows2, cols2 = _mutate(key, rows, cols, N=N, M=M, n=n, m=m,
                           xi=1.0, p_rc=0.5, target=coded.target_col)
    assert (cols2.sum(axis=1) == m).all()
    assert cols2[:, coded.target_col].all()

    rows3, cols3 = _crossover(key, rows2, cols2, N=N, M=M, n=n, m=m,
                              p_rc=0.5, target=coded.target_col)
    assert rows3.shape == (phi, n) and cols3.shape == (phi, M)
    assert (cols3.sum(axis=1) == m).all()
    assert cols3[:, coded.target_col].all()
    assert (rows3 >= 0).all() and (rows3 < N).all()

    fit = -jnp.abs(jax.random.normal(key, (phi,)))
    rows4, cols4 = _select(key, rows3, cols3, fit, alpha=0.25)
    assert rows4.shape == (phi, n)


def test_gen_dst_alternative_measure(coded):
    res = gen_dst(jax.random.key(4), coded, 20, 3,
                  GenDSTConfig(psi=4, phi=8, measure="pnorm"))
    assert int(res.col_mask.sum()) == 3
    assert np.isfinite(float(res.fitness))


def test_crossover_split_sizes_decorrelated():
    """Regression: the row and column split sizes must come from separate
    key folds.  The old code drew both from the same key, so with identical
    ranges (n == m - 1) the two draws were bit-identical every generation —
    row and column crossover geometry moved in lockstep."""
    half, n, m = 256, 10, 11   # randint(1, 10) range for BOTH draws
    for seed in range(3):
        s_r, s_c = _crossover_splits(jax.random.key(seed), half, n, m)
        s_r, s_c = np.asarray(s_r), np.asarray(s_c)
        assert not np.array_equal(s_r, s_c), \
            "row/column split sizes are bit-identical — correlated RNG"
        # and they should look independent, not merely unequal
        assert 0 < (s_r == s_c).mean() < 0.5


def test_gen_dst_batch_validates_config(coded):
    """gen_dst_batch must fail fast on the same bad configs gen_dst rejects
    (it used to skip the islands/cadence validation entirely)."""
    keys = [jax.random.key(0)]
    for bad in (GenDSTConfig(psi=2, phi=8, num_islands=0),
                GenDSTConfig(psi=2, phi=8, cross_every=0),
                GenDSTConfig(psi=2, phi=8, migrate_every=0),
                GenDSTConfig(psi=2, phi=7)):
        with pytest.raises(AssertionError):
            gen_dst(jax.random.key(0), coded, 10, 3, bad)
        with pytest.raises(AssertionError):
            gen_dst_batch(keys, [coded], 10, 3, bad)


def test_gen_dst_unknown_backend_rejected(coded):
    bad = GenDSTConfig(psi=2, phi=8, backend="cuda")
    with pytest.raises(ValueError, match="unknown Gen-DST backend"):
        gen_dst(jax.random.key(0), coded, 10, 3, bad)
    with pytest.raises(ValueError, match="unknown Gen-DST backend"):
        gen_dst_batch([jax.random.key(0)], [coded], 10, 3, bad)


def test_gen_dst_deterministic(coded):
    r1 = gen_dst(jax.random.key(7), coded, 20, 3, CFG)
    r2 = gen_dst(jax.random.key(7), coded, 20, 3, CFG)
    np.testing.assert_array_equal(np.asarray(r1.row_idx), np.asarray(r2.row_idx))
    assert float(r1.fitness) == float(r2.fitness)
