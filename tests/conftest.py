import os
import sys
from pathlib import Path

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (dry-run subprocess tests set it themselves).
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: spawns real worker subprocesses (skippable with -m 'not slow')")
