"""SSD-scan Pallas kernel vs naive-recurrence oracle + model chunked path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.ssd_scan.ops import ssd_scan

CASES = [
    (2, 32, 8, 16, 8, jnp.float32),
    (3, 64, 16, 8, 16, jnp.float32),
    (1, 128, 64, 32, 32, jnp.float32),
    (2, 64, 16, 16, 16, jnp.bfloat16),
]


def _inputs(BH, S, P, N, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.normal(0, 1, (BH, S, P)), dtype),
        jnp.asarray(rng.uniform(0.01, 0.2, (BH, S)), dtype),
        jnp.asarray(-rng.uniform(0.5, 4.0, (BH,)), jnp.float32),
        jnp.asarray(rng.normal(0, 1, (BH, S, N)), dtype),
        jnp.asarray(rng.normal(0, 1, (BH, S, N)), dtype),
    )


@pytest.mark.parametrize("BH,S,P,N,Q,dtype", CASES)
def test_ssd_kernel_matches_recurrence(BH, S, P, N, Q, dtype):
    x, dt, a, bm, cm = _inputs(BH, S, P, N, dtype, seed=S + P)
    yk = ssd_scan_pallas(x, dt, a, bm, cm, block_q=Q)
    yr = ssd_scan_ref(x, dt, a, bm, cm)
    atol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=atol)


def test_ssd_chunk_size_invariance():
    x, dt, a, bm, cm = _inputs(2, 64, 8, 8, jnp.float32)
    y1 = ssd_scan_pallas(x, dt, a, bm, cm, block_q=8)
    y2 = ssd_scan_pallas(x, dt, a, bm, cm, block_q=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_ssd_ops_matches_model_chunked():
    from repro.models.config import ModelConfig
    from repro.models.ssm import _ssd_chunked
    rng = np.random.default_rng(3)
    cfg = ModelConfig("t", "ssm", n_layers=1, d_model=32, vocab_size=8,
                      ssm_state=16, ssm_head_dim=8, ssm_chunk=8)
    B, S, H, P, G, N = 2, 32, 4, 8, 1, 16
    x = jnp.asarray(rng.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 4, (H,)), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (B, S, G, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (B, S, G, N)), jnp.float32)
    y_ops = ssd_scan(x, dt, a, bm, cm, use_pallas=True)
    y_model, _ = _ssd_chunked(x, dt, a, bm, cm, cfg)
    np.testing.assert_allclose(np.asarray(y_ops), np.asarray(y_model, np.float32),
                               atol=1e-3)
