"""HLO cost engine: loop-trip scaling, dot FLOPs, collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_costs import analyze_hlo, xla_cost_dict


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scanned_matmul_flops_exact():
    L, M, K = 7, 128, 256

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    c = _compile(f, jnp.zeros((L, K, K)), jnp.zeros((M, K)))
    cost = analyze_hlo(c.as_text())
    expected = L * 2 * M * K * K
    assert cost.flops == pytest.approx(expected, rel=1e-6)
    assert cost.n_while == 1
    assert list(cost.trip_counts.values()) == [L]
    # XLA's own analysis undercounts by ~L (this is why the engine exists)
    xla = float(xla_cost_dict(c.cost_analysis()).get("flops", 0.0))
    assert xla < expected / 2


def test_nested_scan_flops():
    Lo, Li, M = 3, 5, 32

    def f(ws, x):
        def outer(x, wo):
            def inner(x, wi):
                return x @ wi, None
            y, _ = jax.lax.scan(inner, x, wo)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    c = _compile(f, jnp.zeros((Lo, Li, M, M)), jnp.zeros((M, M)))
    cost = analyze_hlo(c.as_text())
    assert cost.flops == pytest.approx(Lo * Li * 2 * M ** 3, rel=1e-6)


def test_grad_flops_factor():
    M = 64

    def f(w, x):
        return jnp.tanh(x @ w).sum()

    c = _compile(jax.grad(f, argnums=(0, 1)), jnp.zeros((M, M)), jnp.zeros((M, M)))
    cost = analyze_hlo(c.as_text())
    # fwd dot + two bwd dots = 3x
    assert cost.flops == pytest.approx(3 * 2 * M ** 3, rel=1e-6)


def test_bytes_positive_and_sane():
    def f(x):
        return (x @ x).sum()

    x = jnp.zeros((256, 256))
    cost = analyze_hlo(_compile(f, x).as_text())
    assert cost.bytes >= 2 * 256 * 256 * 4  # at least read x twice-ish
    assert cost.flops == pytest.approx(2 * 256 ** 3, rel=1e-6)
