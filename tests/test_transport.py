"""Cross-process serving tier: worker pools, crash recovery, checkpointed
resume, HTTP front end (DESIGN.md §14).

The deterministic chaos tests drive a ``DistributedScheduler`` over
``SimWorkerPool`` — the in-process pool that runs the *same*
``worker.eval_task`` code path and applies ``harness.faultsim`` fault
plans at the same dequeue point as a real worker, with zero timing
dependence.  One test at the bottom repeats the kill scenario against
real spawned subprocesses.
"""
import numpy as np
import pytest

import jax

from harness.faultsim import FaultEvent, FaultPlan
from repro.automl.engine import AutoMLConfig
from repro.core.plan import plan
from repro.service import DistributedScheduler, SimWorkerPool, SubStratServer
from repro.service.cache import DSTCache
from repro.service.scheduler import Scheduler

PLAN = plan("gen_dst", n=24, m=4,
            sub_automl=AutoMLConfig(n_trials=4, rungs=(2, 4)),
            ft_automl=AutoMLConfig(n_trials=2, rungs=(2,)),
            psi=4, phi=10)


def _make(seed, N=48, d=6, c=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, d)).astype(np.float32)
    y = (np.arange(N) % c).astype(np.int64)
    return X, y


def _submit_two(sched):
    X1, y1 = _make(0)
    X2, y2 = _make(1)
    a = sched.submit(X1, y1, key=jax.random.key(1), plan=PLAN)
    b = sched.submit(X2, y2, key=jax.random.key(2), plan=PLAN)
    return a, b


@pytest.fixture(scope="module")
def baseline():
    """Fault-free in-process reference results for the two standard jobs."""
    sched = Scheduler(DSTCache())
    a, b = _submit_two(sched)
    sched.run()
    return {0: sched.jobs[a].result, 1: sched.jobs[b].result}


def _assert_parity(result, want):
    assert result.final.spec == want.final.spec
    np.testing.assert_allclose([v for _, v in result.final.trials],
                               [v for _, v in want.final.trials], atol=1e-6)


def _run_distributed(pool, **kw):
    sched = DistributedScheduler(pool, cache=DSTCache(), **kw)
    a, b = _submit_two(sched)
    sched.run()
    return sched, (a, b)


# ---------------------------------------------------------------------------
# fault-free parity + streamed leaderboards
# ---------------------------------------------------------------------------


def test_sim_pool_matches_in_process(baseline):
    sched, jobs = _run_distributed(SimWorkerPool(2))
    for i, j in enumerate(jobs):
        assert sched.jobs[j].phase == "done"
        _assert_parity(sched.jobs[j].result, baseline[i])
    t = sched.stats()["transport"]
    assert t["remote_tasks"] > 0
    assert t["worker_failures"] == 0


def test_leaderboard_streams_rung_by_rung(baseline):
    sched = Scheduler(DSTCache())
    a, _ = _submit_two(sched)
    server = SubStratServer(scheduler=sched)
    seen, since = [], 0
    while sched.pending():
        sched.step()
        st = server.poll(a, since=since)
        seen.extend(st.leaderboard)
        since = st.leaderboard_total
    # cursor polling delivered every entry exactly once, in order
    assert [e["rung"] for e in seen] == \
        [e["rung"] for e in sched.jobs[a].leaderboard]
    assert len(seen) >= 2                     # sub pass rungs + fine-tune
    assert seen[0]["phase"] == "sub_automl"
    assert seen[-1]["phase"] == "fine_tune"
    for entry in seen:
        accs = [t["val_acc"] for t in entry["top"]]
        assert accs == sorted(accs, reverse=True)
    # final poll with a stale cursor returns only the tail
    st = server.poll(a, since=since)
    assert st.leaderboard == ()


# ---------------------------------------------------------------------------
# chaos: deterministic kill / stall / delay recovery
# ---------------------------------------------------------------------------


def test_chaos_kill_is_deterministic_5_of_5(baseline):
    """The acceptance gate: under a fixed FaultPlan seed, kill one worker
    mid-search on every one of 5 runs — all jobs complete every time, with
    winner specs equal and accuracies within 1e-6 of the fault-free run."""
    # seed 2 deterministically compiles to "kill worker 0 at its first
    # task" — worker 0 always owns task 0 of the first rung dispatch, so
    # the kill lands mid-sub_automl on every run
    fault_plan = FaultPlan.random(seed=2, n_workers=2, actions=("kill",))
    assert fault_plan == FaultPlan.random(seed=2, n_workers=2,
                                          actions=("kill",))
    assert fault_plan.compile() == ((0, 0, "kill", 3600.0),)
    for run in range(5):
        pool = SimWorkerPool(2, fault_events=fault_plan.compile())
        sched, jobs = _run_distributed(pool)
        t = sched.stats()["transport"]
        assert t["worker_failures"] == 1, f"run {run}: kill not observed"
        for i, j in enumerate(jobs):
            assert sched.jobs[j].phase == "done", f"run {run}"
            _assert_parity(sched.jobs[j].result, baseline[i])


def test_stall_recovery_via_no_beat_timeout(baseline):
    """A stalled worker stays in alive_workers(); only the dispatched-with-
    no-beat timeout can catch it."""
    pool = SimWorkerPool(2, fault_events=FaultPlan.stall(0, 0).compile())
    sched, jobs = _run_distributed(pool, stall_timeout_s=0.05, poll_s=0.01)
    t = sched.stats()["transport"]
    assert t["worker_failures"] >= 1
    assert t["redispatched_tasks"] >= 1
    for i, j in enumerate(jobs):
        _assert_parity(sched.jobs[j].result, baseline[i])


def test_delay_does_not_trigger_recovery(baseline):
    """A slow-but-beating worker must not be declared lost."""
    pool = SimWorkerPool(2, fault_events=FaultPlan.delay(0, 0, 0.01).compile())
    sched, jobs = _run_distributed(pool, stall_timeout_s=0.05, poll_s=0.01)
    assert sched.stats()["transport"]["worker_failures"] == 0
    for i, j in enumerate(jobs):
        _assert_parity(sched.jobs[j].result, baseline[i])


def test_all_workers_dead_falls_back_locally(baseline):
    """With no survivors the front end evaluates the remainder itself."""
    fault_plan = FaultPlan.kill(0, 0) + FaultPlan.kill(1, 0)
    pool = SimWorkerPool(2, fault_events=fault_plan.compile())
    sched, jobs = _run_distributed(pool)
    t = sched.stats()["transport"]
    assert t["local_fallbacks"] >= 1
    assert sched.pool.alive_workers() == []
    for i, j in enumerate(jobs):
        assert sched.jobs[j].phase == "done"
        _assert_parity(sched.jobs[j].result, baseline[i])


# ---------------------------------------------------------------------------
# mid-pack failure isolation (the Scheduler._fail satellite)
# ---------------------------------------------------------------------------


def test_mid_pack_failure_does_not_strand_group(baseline, monkeypatch):
    """One poison job in a merged megabatch pack must fail alone: its
    co-riders re-run solo and complete (regression for the group-wide
    _fail)."""
    from repro.automl import batched

    sched = Scheduler(DSTCache())
    a, b = _submit_two(sched)
    real = batched.eval_trial_megabatch

    def poisoned(cohorts):
        # job b's cohort is poison: any dispatch containing it blows up
        ctx_b = (sched.jobs[b].search.ctx
                 if sched.jobs[b].search is not None else None)
        if ctx_b is not None and any(tc.ctx is ctx_b for tc in cohorts):
            raise RuntimeError("poison cohort")
        return real(cohorts)

    # the scheduler imports the symbol at dispatch time, so patching the
    # batched module is enough
    monkeypatch.setattr(batched, "eval_trial_megabatch", poisoned)
    sched.run()

    assert sched.jobs[b].phase == "failed"
    assert "poison" in repr(sched.jobs[b].error)
    assert sched.jobs[a].phase == "done", \
        "innocent co-rider stranded by a mid-pack failure"
    _assert_parity(sched.jobs[a].result, baseline[0])
    assert sched.poisoned_packs >= 1


def test_whole_group_failure_fails_every_job():
    """When every member also fails solo, all of them are marked failed."""
    from repro.automl import batched

    sched = Scheduler(DSTCache())
    a, b = _submit_two(sched)

    def always_broken(cohorts):
        raise RuntimeError("backend down")

    import unittest.mock as mock
    with mock.patch.object(batched, "eval_trial_megabatch", always_broken):
        sched.run()
    assert sched.jobs[a].phase == "failed"
    assert sched.jobs[b].phase == "failed"


# ---------------------------------------------------------------------------
# scheduler checkpoint / resume
# ---------------------------------------------------------------------------


def test_checkpointed_front_end_resumes_bit_identically(tmp_path, baseline):
    """Kill the front end mid-flight; a fresh scheduler restores the last
    per-step checkpoint and finishes with fault-free results."""
    ckpt = tmp_path / "ckpt"
    sched = DistributedScheduler(SimWorkerPool(2), cache=DSTCache(),
                                 ckpt_dir=ckpt)
    a, b = _submit_two(sched)
    # one step cascades factorize → dst → first sub_automl rung, so the
    # "crash" lands mid-search with one rung recorded
    sched.step()
    assert any(j.search is not None for j in sched.jobs.values()), \
        "crash point must land mid-search to exercise SearchState restore"
    del sched

    fresh = DistributedScheduler(SimWorkerPool(2), cache=DSTCache(),
                                 ckpt_dir=ckpt)
    step = fresh.resume()
    assert step == 1
    assert set(fresh.jobs) == {a, b}
    fresh.run()
    for i, j in enumerate((a, b)):
        assert fresh.jobs[j].phase == "done"
        _assert_parity(fresh.jobs[j].result, baseline[i])


def test_snapshot_preserves_leaderboard_and_counters():
    sched = Scheduler(DSTCache())
    a, b = _submit_two(sched)
    sched.run()
    blob = sched.snapshot()
    fresh = Scheduler(DSTCache())
    fresh.load_snapshot(blob)
    assert fresh.jobs[a].leaderboard == sched.jobs[a].leaderboard
    assert fresh.solo_rungs == sched.solo_rungs
    assert fresh.merged_rungs == sched.merged_rungs
    assert fresh._next_id == sched._next_id
    # the DST cache came along: a repeat submission is a hit
    X1, y1 = _make(0)
    c = fresh.submit(X1, y1, key=jax.random.key(9), plan=PLAN)
    fresh.run()
    assert fresh.jobs[c].cache_hit


# ---------------------------------------------------------------------------
# real subprocesses: spawn pool + kill + HTTP round trip
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_process_pool_chaos_with_http(baseline):
    """The kill scenario against real spawned workers, served over HTTP:
    worker 0 dies mid-protocol (os._exit), the front end re-dispatches to
    the survivor, and both jobs finish with fault-free parity."""
    from repro.service import (ProcessWorkerPool, SubStratHTTPClient,
                               SubStratHTTPServer)

    pool = ProcessWorkerPool(2, fault_events=FaultPlan.kill(0, 0).compile())
    sched = DistributedScheduler(pool, cache=DSTCache(), stall_timeout_s=60.0)
    http = SubStratHTTPServer(SubStratServer(scheduler=sched)).start()
    try:
        client = SubStratHTTPClient(http.url)
        X1, y1 = _make(0)
        X2, y2 = _make(1)
        a = client.submit(X1, y1, key=jax.random.key(1), plan=PLAN)
        b = client.submit(X2, y2, key=jax.random.key(2), plan=PLAN)
        entries = list(client.stream_leaderboard(a))
        assert len(entries) >= 2
        _assert_parity(client.result(a), baseline[0])
        _assert_parity(client.result(b), baseline[1])
        stats = client.stats()
        assert stats["transport"]["worker_failures"] == 1
        assert stats["transport"]["redispatched_tasks"] >= 1
    finally:
        http.close()
        sched.close()


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultEvent(0, 0, "explode")
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent(-1, 0, "kill")
    compiled = FaultPlan.kill(1, 2).compile()
    assert compiled == ((1, 2, "kill", 0.0),)
