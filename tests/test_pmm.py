"""Sharding-aware custom-VJP matmul: autodiff equivalence (the sharding
behaviour itself is exercised by the dry-run + subprocess tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.pmm import matmul

SUBS = [
    ("bsd,df->bsf", (2, 8, 16), (16, 32)),
    ("bsf,fd->bsd", (2, 8, 32), (32, 16)),
    ("bsd,dhk->bshk", (2, 8, 16), (16, 4, 8)),
    ("bshk,hkd->bsd", (2, 8, 4, 8), (4, 8, 16)),
    ("ecd,edf->ecf", (4, 8, 16), (4, 16, 8)),
    ("ecf,efd->ecd", (4, 8, 16), (4, 16, 8)),
]


@pytest.mark.parametrize("subs,xs,ws", SUBS, ids=[s for s, *_ in SUBS])
def test_matmul_grads_match_einsum(subs, xs, ws):
    x = jax.random.normal(jax.random.key(0), xs)
    w = jax.random.normal(jax.random.key(1), ws)

    def f_pmm(x, w):
        return (matmul(x, w, subs, None) ** 2).sum()

    def f_ein(x, w):
        return (jnp.einsum(subs, x, w) ** 2).sum()

    np.testing.assert_allclose(float(f_pmm(x, w)), float(f_ein(x, w)), rtol=1e-5)
    g1 = jax.grad(f_pmm, argnums=(0, 1))(x, w)
    g2 = jax.grad(f_ein, argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_matmul_under_remat_and_scan():
    w = jax.random.normal(jax.random.key(0), (3, 16, 16))
    x = jax.random.normal(jax.random.key(1), (2, 4, 16))

    @jax.checkpoint
    def layer(x, w):
        return jax.nn.relu(matmul(x, w, "bsd,df->bsf", None))

    def loss(x, ws):
        def body(x, w):
            return layer(x, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return (y ** 2).sum()

    g = jax.grad(loss, argnums=1)(x, w)
    assert np.isfinite(np.asarray(g)).all()
    # reference without the wrapper
    def loss_ref(x, ws):
        def body(x, w):
            return jax.nn.relu(jnp.einsum("bsd,df->bsf", x, w)), None
        y, _ = jax.lax.scan(body, x, ws)
        return (y ** 2).sum()
    g_ref = jax.grad(loss_ref, argnums=1)(x, w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4,
                               atol=1e-5)
