"""Kernel backends wired into the model stack: pallas_interpret forward
matches the XLA path end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import lm


def test_dense_forward_pallas_attention_matches():
    cfg = ModelConfig("t", "dense", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
                      remat=False, dtype="float32")
    params = lm.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 128), 0, 64)
    y_xla = lm.forward(params, {"tokens": toks}, cfg)
    cfg_p = dataclasses.replace(cfg, attn_impl="pallas_interpret")
    y_pls = lm.forward(params, {"tokens": toks}, cfg_p)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pls),
                               atol=2e-4, rtol=2e-4)


def test_ssm_forward_pallas_matches():
    cfg = ModelConfig("t", "ssm", n_layers=2, d_model=32, vocab_size=64,
                      ssm_state=16, ssm_head_dim=8, ssm_chunk=16,
                      remat=False, dtype="float32")
    params = lm.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, 64)
    y_xla = lm.forward(params, {"tokens": toks}, cfg)
    cfg_p = dataclasses.replace(cfg, ssm_impl="pallas_interpret")
    y_pls = lm.forward(params, {"tokens": toks}, cfg_p)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pls),
                               atol=2e-3, rtol=2e-3)
