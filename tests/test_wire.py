"""service/wire.py: versioned serialization + cross-process SearchState
resume (DESIGN.md §14.2, §14.4).

Covers the wire acceptance contract — exact round-trips for index/int
tensors, version rejection with a clear error — plus the crash/resume
satellite: a ``SearchState`` serialized mid-rung and restored in a *fresh
process* finishes with the same winner spec and trial accuracies within
1e-6 of the uninterrupted run.
"""
import dataclasses
import json
import os
import struct
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.automl.engine import (
    AutoMLConfig, search_eval_rung, search_init, search_restore,
    search_result, search_snapshot,
)
from repro.core.gen_dst import GenDSTConfig
from repro.core.measures import factorize
from repro.core.plan import plan
from repro.service import wire

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _roundtrip(obj):
    return wire.loads(wire.dumps(obj))


# ---------------------------------------------------------------------------
# exact round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32, np.int64,
                                   np.uint8, np.uint32, np.bool_])
def test_int_index_tensors_roundtrip_exact(dtype):
    rng = np.random.default_rng(0)
    arr = rng.integers(0, 100, size=(7, 3)).astype(dtype)
    out = _roundtrip(arr)
    assert out.dtype == arr.dtype
    assert out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


@pytest.mark.parametrize("dtype", [np.float16, np.float32, np.float64])
def test_float_tensors_roundtrip_bit_exact(dtype):
    rng = np.random.default_rng(1)
    arr = rng.normal(size=(5, 4)).astype(dtype)
    out = _roundtrip(arr)
    assert out.dtype == arr.dtype
    # this codec ships raw buffers: floats are bit-exact, not just close
    np.testing.assert_array_equal(
        out.view(np.uint8), np.ascontiguousarray(arr).view(np.uint8))


def test_empty_and_scalar_arrays():
    empty = np.empty((0, 5), np.int64)
    out = _roundtrip(empty)
    assert out.shape == (0, 5) and out.dtype == np.int64
    scalar = np.float32(2.5)
    back = _roundtrip(scalar)
    assert isinstance(back, np.floating) and back == scalar


def test_decoded_arrays_are_writable_copies():
    arr = np.arange(6, dtype=np.int32)
    out = _roundtrip(arr)
    out[0] = 99        # frombuffer views are read-only; we require copies
    assert arr[0] == 0


def test_nested_structures_roundtrip():
    obj = {
        "ints": np.arange(4, dtype=np.int64),
        "tup": (1, "two", 3.0, None, True),
        "nested": [{"k": (np.float32(1.5), b"raw-bytes")}],
        7: "non-string-key",
    }
    out = _roundtrip(obj)
    assert out["tup"] == (1, "two", 3.0, None, True)
    assert out["nested"][0]["k"][1] == b"raw-bytes"
    assert out[7] == "non-string-key"
    np.testing.assert_array_equal(out["ints"], obj["ints"])


def test_prng_key_roundtrip():
    key = jax.random.key(42)
    out = _roundtrip(key)
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(out)),
        np.asarray(jax.random.key_data(key)))
    # the restored key *is* a key: splitting works and matches
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(jax.random.split(out)[0])),
        np.asarray(jax.random.key_data(jax.random.split(key)[0])))


def test_repro_dataclasses_and_namedtuples_roundtrip():
    p = plan("gen_dst", n=32, m=4,
             sub_automl=AutoMLConfig(n_trials=6, rungs=(2, 4)), psi=5)
    assert _roundtrip(p) == p
    cfg = GenDSTConfig(psi=3, phi=8, measure="ig")
    assert _roundtrip(cfg) == cfg
    rng = np.random.default_rng(0)
    coded = factorize(rng.normal(size=(20, 4)).astype(np.float32),
                      (np.arange(20) % 2).astype(np.int64))
    back = _roundtrip(coded)
    assert type(back).__name__ == "CodedDataset"   # typed, not a bare tuple
    np.testing.assert_array_equal(np.asarray(back.codes),
                                  np.asarray(coded.codes))
    assert back.target_col == coded.target_col


def test_kind_tag_peek():
    blob = wire.dumps({"x": 1}, kind="task")
    assert wire.kind_of(blob) == "task"


# ---------------------------------------------------------------------------
# rejection paths
# ---------------------------------------------------------------------------


def test_unknown_version_rejected_with_clear_error():
    blob = bytearray(wire.dumps({"x": 1}))
    struct.pack_into("<I", blob, 4, wire.WIRE_VERSION + 1)   # bump version
    with pytest.raises(wire.WireVersionError) as exc:
        wire.loads(bytes(blob))
    msg = str(exc.value)
    assert str(wire.WIRE_VERSION + 1) in msg
    assert str(wire.WIRE_VERSION) in msg          # names both versions


def test_bad_magic_rejected():
    blob = b"XXXX" + wire.dumps({"x": 1})[4:]
    with pytest.raises(wire.WireError, match="magic"):
        wire.loads(blob)


def test_truncated_payload_rejected():
    blob = wire.dumps(np.arange(100, dtype=np.int64))
    with pytest.raises(wire.WireError, match="truncated"):
        wire.loads(blob[:-8])
    with pytest.raises(wire.WireError):
        wire.loads(blob[:6])


def test_callables_rejected_by_name():
    with pytest.raises(wire.WireError, match="not wire-serializable"):
        wire.dumps({"thunk": lambda: 1})


def test_foreign_dataclass_rejected():
    @dataclasses.dataclass
    class NotOurs:
        x: int = 1

    with pytest.raises(wire.WireError, match="non-repro"):
        wire.dumps(NotOurs())


def test_decode_refuses_foreign_module_tags():
    # a crafted payload may not import arbitrary modules
    blob = wire.dumps(GenDSTConfig())
    evil = blob.replace(b"repro.core.gen_dst", b"os.path:::::::juno")
    with pytest.raises((wire.WireError, Exception)):
        wire.loads(evil)


# ---------------------------------------------------------------------------
# SearchState snapshot: in-process and across a real process boundary
# ---------------------------------------------------------------------------


def _mini_search(seed=0, N=48, d=6, c=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, d)).astype(np.float32)
    y = (np.arange(N) % c).astype(np.int64)
    return search_init(X, y, config=AutoMLConfig(n_trials=6, rungs=(2, 4)))


def test_search_snapshot_roundtrip_in_process():
    golden = _mini_search()
    while not golden.done:
        search_eval_rung(golden)
    want = search_result(golden)

    st = _mini_search()
    search_eval_rung(st)                       # mid-search: one rung recorded
    snap = wire.loads(wire.dumps(search_snapshot(st), kind="search"))
    resumed = search_restore(snap)
    while not resumed.done:
        search_eval_rung(resumed)
    got = search_result(resumed)

    assert got.spec == want.spec
    np.testing.assert_allclose([v for _, v in got.trials],
                               [v for _, v in want.trials], atol=1e-6)


def test_search_snapshot_resumes_in_fresh_process(tmp_path):
    """The crash/resume satellite: wire a mid-rung SearchState to disk,
    finish it in a *fresh* interpreter, compare with the uninterrupted run."""
    golden = _mini_search()
    while not golden.done:
        search_eval_rung(golden)
    want = search_result(golden)

    st = _mini_search()
    search_eval_rung(st)
    blob_path = tmp_path / "search.wire"
    blob_path.write_bytes(wire.dumps(search_snapshot(st), kind="search"))

    script = textwrap.dedent(f"""
        import json, sys
        from repro.automl.engine import (search_eval_rung, search_restore,
                                         search_result)
        from repro.service import wire
        snap = wire.loads(open({str(blob_path)!r}, "rb").read())
        st = search_restore(snap)
        while not st.done:
            search_eval_rung(st)
        res = search_result(st)
        print(json.dumps({{
            "spec": [res.spec.preproc, res.spec.feature_frac,
                     res.spec.family, list(map(list, res.spec.hp))],
            "trials": [float(v) for _, v in res.trials],
        }}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    got = json.loads(proc.stdout.strip().splitlines()[-1])

    assert got["spec"][0] == want.spec.preproc
    assert got["spec"][1] == pytest.approx(want.spec.feature_frac)
    assert got["spec"][2] == want.spec.family
    assert tuple(tuple(kv) for kv in got["spec"][3]) == tuple(
        tuple(kv) for kv in want.spec.hp)
    np.testing.assert_allclose(got["trials"],
                               [v for _, v in want.trials], atol=1e-6)
