"""Dataset-entropy measure: paper worked-example values + invariances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env — deterministic fallback, same API subset
    from _hyp_fallback import given, settings, strategies as st

from repro.core.measures import (
    factorize, dataset_entropy, subset_entropy, full_column_entropy,
    column_counts, column_entropy_from_counts,
    measure_pnorm, measure_mean_correlation, measure_coeff_variation,
)

# Table 1 of the paper (flight service review sample)
X_PAPER = np.array([
    [25, 1, 460, 18], [62, 1, 460, 0], [25, 0, 460, 40], [41, 0, 460, 0],
    [27, 1, 460, 0], [41, 1, 1061, 0], [20, 0, 1061, 0], [25, 0, 1061, 51],
    [13, 0, 1061, 0], [52, 1, 1061, 0]], dtype=float)
Y_PAPER = np.array([1, 0, 1, 1, 1, 0, 0, 0, 1, 1], dtype=float)


@pytest.fixture(scope="module")
def coded_paper():
    return factorize(X_PAPER, Y_PAPER)


def test_paper_example_full_entropy(coded_paper):
    """Example 3.5: H(D) = 1.395."""
    h = float(dataset_entropy(coded_paper.codes, coded_paper.max_bins))
    assert abs(h - 1.395) < 5e-3


def test_paper_example_column_entropies(coded_paper):
    hcols = np.asarray(full_column_entropy(coded_paper.codes, coded_paper.max_bins))
    # paper: 2.65, 1, 1, 1.4(≈1.36 exact), 0.97
    np.testing.assert_allclose(hcols[0], 2.646, atol=5e-3)
    np.testing.assert_allclose(hcols[1], 1.0, atol=5e-3)
    np.testing.assert_allclose(hcols[2], 1.0, atol=5e-3)
    np.testing.assert_allclose(hcols[4], 0.971, atol=5e-3)


def test_paper_example_green_red_dsts(coded_paper):
    """Example 3.5: H(d_green)=1.42 (measure-preserving), H(d_red)=0.89."""
    green_rows = jnp.array([0, 1, 2, 5, 7])
    green_cols = jnp.zeros(5, bool).at[jnp.array([0, 3, 4])].set(True)
    red_rows = jnp.array([3, 4, 6, 8, 9])
    red_cols = jnp.zeros(5, bool).at[jnp.array([1, 2, 4])].set(True)
    hg = float(subset_entropy(coded_paper.codes, green_rows, green_cols, coded_paper.max_bins))
    hr = float(subset_entropy(coded_paper.codes, red_rows, red_cols, coded_paper.max_bins))
    assert abs(hg - 1.42) < 0.01
    assert abs(hr - 0.89) < 0.01
    h_full = float(dataset_entropy(coded_paper.codes, coded_paper.max_bins))
    assert abs(hg - h_full) < abs(hr - h_full)  # green preserves, red doesn't


def test_full_entropy_chunking_consistent(coded_paper):
    h1 = full_column_entropy(coded_paper.codes, coded_paper.max_bins, chunk=4)
    h2 = full_column_entropy(coded_paper.codes, coded_paper.max_bins, chunk=1024)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(2, 6), st.integers(0, 1000))
def test_entropy_row_permutation_invariant(n, m, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 5, (n, m)), jnp.int32)
    perm = jnp.asarray(rng.permutation(n))
    h1 = dataset_entropy(codes, 8)
    h2 = dataset_entropy(codes[perm], 8)
    assert abs(float(h1) - float(h2)) < 1e-5


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(2, 5), st.integers(0, 1000))
def test_entropy_bounds(n, m, seed):
    """0 <= H_j <= log2(n): entropy of n samples is at most log2 n."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 7, (n, m)), jnp.int32)
    h = float(dataset_entropy(codes, 8))
    assert -1e-6 <= h <= np.log2(n) + 1e-6


def test_constant_column_zero_entropy():
    codes = jnp.zeros((16, 3), jnp.int32)
    assert float(dataset_entropy(codes, 4)) == pytest.approx(0.0, abs=1e-6)


def test_factorize_quantile_binning():
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (5000, 2))
    coded = factorize(X, rng.integers(0, 2, 5000), max_bins=64)
    assert int(coded.n_bins.max()) <= 64
    assert coded.codes.shape == (5000, 3)
    # codes preserve order: higher raw value => code >= (monotone binning)
    col = np.asarray(coded.values[:, 0])
    cds = np.asarray(coded.codes[:, 0])
    order = np.argsort(col)
    assert (np.diff(cds[order]) >= 0).all()


def test_alternative_measures_run(coded_paper):
    rows = jnp.array([0, 1, 2, 5, 7])
    cols = jnp.zeros(5, bool).at[jnp.array([0, 3, 4])].set(True)
    for fn in (measure_pnorm, measure_mean_correlation, measure_coeff_variation):
        full = float(fn(coded_paper.values))
        sub = float(fn(coded_paper.values, rows, cols))
        assert np.isfinite(full) and np.isfinite(sub)


@pytest.mark.parametrize("fn", [measure_pnorm, measure_mean_correlation,
                                measure_coeff_variation])
def test_measures_row_idx_without_col_mask(fn, coded_paper):
    """Registry contract: fn(values, row_idx) with col_mask=None must mean
    "all columns" — it used to crash on col_mask.astype(None-type)."""
    rows = jnp.array([0, 1, 2, 5, 7])
    all_cols = jnp.ones(coded_paper.values.shape[1], bool)
    got = float(fn(coded_paper.values, rows))                  # must not crash
    want = float(fn(coded_paper.values, rows, all_cols))
    assert np.isfinite(got)
    assert got == pytest.approx(want, abs=1e-6)


def test_weighted_counts_match_subset():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(0, 6, (50, 4)), jnp.int32)
    rows = jnp.asarray(rng.choice(50, 12, replace=False))
    mask = jnp.zeros((50,)).at[rows].set(1.0)
    c_mask = column_counts(codes, 8, weights=mask)
    c_gather = column_counts(jnp.take(codes, rows, axis=0), 8)
    np.testing.assert_allclose(np.asarray(c_mask), np.asarray(c_gather))
