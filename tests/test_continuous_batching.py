"""Continuous rung batching (DESIGN.md §13): step-masked fits, the
scheduler-wide megabatch packing policy, and property-test hardening of the
scheduler's dispatch invariants.

Layers under test, bottom up:

- ``models.adam_train(n_steps=...)`` — a step-masked trial inside a longer
  scan must be bit-identical to a solo run of its own length (§13.1);
- ``batched.eval_trial_megabatch`` — cross-rung same-shape merges are
  bit-identical to solo execution, and resuming a search across a rung
  boundary into a megabatch changes nothing (§13.3);
- ``scheduler.merge_waste`` / ``pack_megabatches`` — packing is an exact
  partition, respects the waste budget, is deterministic, and prices class
  padding (the axis the old per-axis ``hetero_pad_limit`` guard ignored);
- the ``Scheduler`` dispatch loop — property-based: random job fleets must
  dispatch every trial exactly once per rung, never train a trial past its
  rung's epoch budget, and never pack a group beyond the waste budget.

Property tests use ``hypothesis`` when installed and fall back to the
deterministic ``_hyp_fallback`` shim otherwise (CI runs both legs).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # minimal environments
    from _hyp_fallback import given, settings, strategies as st

from repro.automl.engine import (
    AutoMLConfig, automl_fit, search_init, search_record, search_result,
    search_trial_cohort,
)
from repro.automl.models import adam_train
from repro.core.plan import plan
from repro.service import SubStratServer
from repro.service.scheduler import (
    CohortMeta, Scheduler, merge_waste, pack_megabatches,
)


def _make(seed, N=240, d=6, c=2):
    r = np.random.default_rng(seed)
    y = r.integers(0, c, N)
    X = np.column_stack(
        [y * 1.4 + r.normal(0, 0.9, N) for _ in range(d)]).astype(np.float32)
    return X, y


# ---------------------------------------------------------------------------
# §13.1 step mask: a short trial inside a long scan is bitwise its solo run
# ---------------------------------------------------------------------------


def _quad_grad(target):
    return jax.grad(lambda p: sum(jnp.sum((x - t) ** 2)
                                  for x, t in zip(p, target)))


def test_adam_step_mask_bit_identical():
    p0 = [jnp.asarray([0.0, 1.0, -2.0]), jnp.asarray([[3.0, -1.0]])]
    target = [jnp.asarray([1.0, -1.0, 0.5]), jnp.asarray([[0.0, 2.0]])]
    grad_fn = _quad_grad(target)
    for k in (0, 1, 3, 8):
        solo = adam_train(grad_fn, p0, 0.05, k)
        masked = adam_train(grad_fn, p0, 0.05, 8, n_steps=jnp.asarray(k))
        for a, b in zip(solo, masked):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adam_step_mask_vmapped_mixed_budgets():
    """One vmapped scan, per-trial budgets — each row equals its solo run."""
    p0 = [jnp.asarray([0.0, 1.0, -2.0])]
    grad_fn = _quad_grad([jnp.asarray([1.0, -1.0, 0.5])])
    budgets = jnp.asarray([2, 8, 5, 0])
    stacked = [jnp.broadcast_to(p0[0], (4,) + p0[0].shape)]
    out = jax.vmap(
        lambda p, n: adam_train(grad_fn, [p[0]], 0.05, 8, n_steps=n)
    )(stacked, budgets)
    for row, k in enumerate(np.asarray(budgets)):
        solo = adam_train(grad_fn, p0, 0.05, int(k))
        np.testing.assert_array_equal(np.asarray(out[0][row]),
                                      np.asarray(solo[0]))


# ---------------------------------------------------------------------------
# §13.3 engine-level parity: cross-rung megabatch == solo, bit for bit
# ---------------------------------------------------------------------------


def _run_megabatched_pair(stA, stB):
    """Drive A one rung ahead, then dispatch A(rung 1) with B(rung 0) in one
    cross-rung megabatch, then finish B solo.  Exercises per-trial rung
    cursors and step masks on the real batched engine."""
    from repro.automl.batched import eval_trial_megabatch

    (outA,) = eval_trial_megabatch([search_trial_cohort(stA)])
    search_record(stA, *outA, 0.0)
    tcA, tcB = search_trial_cohort(stA), search_trial_cohort(stB)
    assert set(tcA.trial_rungs) == {1} and set(tcB.trial_rungs) == {0}
    assert tcA.trial_steps != tcB.trial_steps    # genuinely mixed budgets
    outA, outB = eval_trial_megabatch([tcA, tcB])
    search_record(stA, *outA, 0.0)
    search_record(stB, *outB, 0.0)
    (outB,) = eval_trial_megabatch([search_trial_cohort(stB)])
    search_record(stB, *outB, 0.0)
    assert stA.done and stB.done


@pytest.fixture(scope="module")
def megabatch_parity():
    cfg = lambda s: AutoMLConfig(n_trials=6, rungs=(5, 12), seed=s,
                                 backend="batched")
    XA, yA = _make(0)
    XB, yB = _make(1)
    solo = (automl_fit(XA, yA, config=cfg(0)),
            automl_fit(XB, yB, config=cfg(1)))
    stA = search_init(XA, yA, config=cfg(0))
    stB = search_init(XB, yB, config=cfg(1))
    _run_megabatched_pair(stA, stB)
    return solo, (search_result(stA), search_result(stB))


def test_cross_rung_megabatch_bit_identical(megabatch_parity):
    solo, mega = megabatch_parity
    for ref, got in zip(solo, mega):
        assert got.spec == ref.spec
        assert [s for s, _ in got.trials] == [s for s, _ in ref.trials]
        np.testing.assert_array_equal([v for _, v in got.trials],
                                      [v for _, v in ref.trials])


def test_resume_across_rung_boundary(megabatch_parity):
    """A search advanced solo past a rung boundary and then merged into a
    megabatch is bit-identical to its uninterrupted run — the per-trial
    cursors carry exactly the state the next rung needs."""
    (refA, _), (gotA, _) = megabatch_parity
    assert gotA.val_acc == refA.val_acc
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(gotA.params)[0]),
        np.asarray(jax.tree.leaves(refA.params)[0]))


def test_cursors_advance_only_for_survivors():
    """Promotion advances exactly the surviving trials' cursors; culled
    trials leave the megabatch with their cursor frozen."""
    from repro.automl.batched import eval_trial_megabatch

    X, y = _make(3)
    st_ = search_init(X, y, config=AutoMLConfig(
        n_trials=8, rungs=(4, 9), keep_frac=0.5, backend="batched"))
    assert st_.trial_rung == {i: 0 for i in range(8)}
    (out,) = eval_trial_megabatch([search_trial_cohort(st_)])
    search_record(st_, *out, 0.0)
    survivors = set(st_.alive_ids)
    assert 0 < len(survivors) < 8
    for tid, rung in st_.trial_rung.items():
        assert rung == (1 if tid in survivors else 0)


# ---------------------------------------------------------------------------
# packing policy: waste pricing + the class-padding regression
# ---------------------------------------------------------------------------


def test_merge_waste_prices_class_padding():
    """Satellite regression: a cohort narrow in rows but wide in classes.
    The old guard compared axes (rows, val rows, features) only, so seven
    c=2 jobs padded 12x across the class axis slipped through; the unified
    waste measure prices it."""
    narrow = [CohortMeta((200, 70, 6, 2), (15,) * 6) for _ in range(7)]
    wide = CohortMeta((180, 60, 6, 24), (15,) * 6)
    # the old per-axis row/feature check would admit this bucket
    shapes = [m.shape for m in narrow + [wide]]
    assert all(max(s[a] for s in shapes) <= 4.0 * min(s[a] for s in shapes)
               for a in (0, 1, 2))
    assert merge_waste(narrow + [wide]) > 4.0
    metas = narrow + [wide]
    groups = pack_megabatches(metas, 4.0)
    # the all-in-one merge is refused; whatever does share a dispatch with
    # the wide cohort stays within the budget
    assert len(groups) > 1
    wide_group = next(g for g in groups if 7 in g)
    assert merge_waste([metas[i] for i in wide_group]) <= 4.0


def test_merge_waste_prices_step_padding():
    """Scan-length padding counts at identical data shapes.  A single
    1-epoch cohort rides a 60-epoch scan almost for free (that asymmetry is
    the point of continuous batching), but a *fleet* of short cohorts
    padded to one long scan is priced and split."""
    short = CohortMeta((200, 70, 6, 2), (1,) * 6)
    long_ = CohortMeta((200, 70, 6, 2), (60,) * 6)
    assert merge_waste([short]) == pytest.approx(1.0)
    assert merge_waste([short, long_]) < 4.0          # lone passenger: cheap
    fleet = [short] * 7 + [long_]
    assert merge_waste(fleet) > 4.0
    for g in pack_megabatches(fleet, 4.0):
        assert merge_waste([fleet[i] for i in g]) <= 4.0
    assert len(pack_megabatches(fleet, 4.0)) > 1


def test_lockstep_plan_bucket_rejects_class_padding():
    """The fixed lockstep guard (megabatch=False) refuses the narrow-rows/
    wide-classes bucket end to end: no dispatched group mixes class counts."""
    from repro.automl import batched

    log = []
    real = batched.eval_rung_cohorts

    def spy(cohorts, collect_params=None):
        log.append([tc.shape for tc in cohorts])
        return real(cohorts, collect_params)

    sched = Scheduler(megabatch=False)
    pl = plan("random", fine_tune=False,
              sub_automl=AutoMLConfig(n_trials=4, rungs=(4,)))
    for i in range(7):
        X, y = _make(10 + i, 150, 5, 2)
        sched.submit(X, y, key=jax.random.key(i), plan=pl)
    Xw, yw = _make(20, 400, 5, 20)
    sched.submit(Xw, yw, key=jax.random.key(9), plan=pl)
    batched.eval_rung_cohorts = spy
    try:
        sched.run()
    finally:
        batched.eval_rung_cohorts = real
    assert all(j.phase == "done" for j in sched.jobs.values())
    for shapes in log:
        assert len({s[3] for s in shapes}) == 1   # never mixes class counts


# ---------------------------------------------------------------------------
# property tests: pack_megabatches invariants
# ---------------------------------------------------------------------------


def _random_metas(rng):
    metas = []
    for _ in range(int(rng.integers(1, 11))):
        shape = (int(rng.integers(20, 3000)), int(rng.integers(8, 1000)),
                 int(rng.integers(2, 30)), int(rng.integers(2, 13)))
        steps = tuple(int(rng.integers(1, 61))
                      for _ in range(int(rng.integers(1, 9))))
        metas.append(CohortMeta(shape, steps))
    return metas


@settings(max_examples=40)
@given(st.integers(0, 10**6), st.floats(1.2, 10.0))
def test_pack_megabatches_invariants(seed, budget):
    rng = np.random.default_rng(seed)
    metas = _random_metas(rng)
    groups = pack_megabatches(metas, budget)
    # exact partition: every cohort in exactly one group
    flat = sorted(i for g in groups for i in g)
    assert flat == list(range(len(metas)))
    # every multi-cohort group respects the waste budget
    for g in groups:
        if len(g) > 1:
            assert merge_waste([metas[i] for i in g]) <= budget + 1e-9
    # deterministic
    assert pack_megabatches(metas, budget) == groups
    # same_shape_only groups never mix shapes and never mask rows/classes
    for g in pack_megabatches(metas, budget, same_shape_only=True):
        assert len({metas[i].shape for i in g}) == 1


@settings(max_examples=20)
@given(st.integers(0, 10**6))
def test_merge_waste_bounds(seed):
    rng = np.random.default_rng(seed)
    metas = _random_metas(rng)
    for m in metas:
        if len(set(m.steps)) == 1:
            assert merge_waste([m]) == pytest.approx(1.0)
        else:
            assert merge_waste([m]) >= 1.0
    # merging can only add padding: waste >= any member's solo waste
    assert merge_waste(metas) >= max(merge_waste([m]) for m in metas) - 1e-12


# ---------------------------------------------------------------------------
# property tests: scheduler dispatch invariants under random job fleets
# ---------------------------------------------------------------------------

_RUNG_MENU = ((4,), (2, 5), (3, 8), (5,), (2, 4, 7))


def _fake_eval(log):
    """Stand-in for ``eval_trial_megabatch``: deterministic accuracies keyed
    by (job seed, trial id), no device work.  Records every dispatch."""
    def fake(cohorts, collect_params=None):
        log.append(cohorts)
        outs = []
        for tc in cohorts:
            scored = []
            for pos, spec in enumerate(tc.specs):
                tid = int(tc.tids[pos])
                vacc = ((int(tc.ctx["seed"]) * 31 + tid * 7) % 97) / 97.0
                scored.append((spec, vacc, {}, np.arange(2), {}))
            outs.append((scored, list(range(len(tc.specs)))))
        return outs
    return fake


@settings(max_examples=15)
@given(st.integers(0, 10**6), st.floats(1.5, 8.0))
def test_scheduler_dispatch_invariants(seed, budget):
    """Random fleets of jobs (random shapes, rung ladders, trial counts):

    - every submitted trial is dispatched exactly once per rung it survives,
    - a trial never trains past its rung's epoch budget,
    - no dispatched group exceeds the configured waste budget,
    - every job completes."""
    from repro.automl import batched

    rng = np.random.default_rng(seed)
    n_jobs = int(rng.integers(2, 7))
    sched = Scheduler(megabatch=True, waste_budget=budget)
    rungs_of = {}
    for i in range(n_jobs):
        rungs = _RUNG_MENU[int(rng.integers(0, len(_RUNG_MENU)))]
        n_trials = int(rng.integers(2, 7))
        X, y = _make(100 + i, int(rng.integers(40, 400)),
                     int(rng.integers(3, 9)), int(rng.integers(2, 5)))
        pl = plan("random", fine_tune=False,
                  sub_automl=AutoMLConfig(n_trials=n_trials, rungs=rungs,
                                          seed=i, backend="batched"))
        jid = sched.submit(X, y, key=jax.random.key(i), plan=pl)
        rungs_of[i] = rungs
        assert jid == i
    log = []
    real = batched.eval_trial_megabatch
    batched.eval_trial_megabatch = _fake_eval(log)
    try:
        sched.run()
    finally:
        batched.eval_trial_megabatch = real

    assert all(j.phase == "done" for j in sched.jobs.values())
    dispatched = {}                       # (job seed, tid, rung) -> count
    for group in log:
        if len(group) > 1:
            metas = [CohortMeta(tc.shape, tc.trial_steps) for tc in group]
            assert merge_waste(metas) <= budget + 1e-9
        for tc in group:
            job_seed = int(tc.ctx["seed"])
            for pos, tid in enumerate(tc.tids):
                rung = tc.trial_rungs[pos]
                steps = tc.trial_steps[pos]
                # budget: exactly this rung's epochs, never beyond
                assert steps == rungs_of[job_seed][rung]
                key = (job_seed, int(tid), rung)
                dispatched[key] = dispatched.get(key, 0) + 1
    # exactly-once per (job, trial, rung)
    assert dispatched and set(dispatched.values()) == {1}
    # every job's rung 0 dispatched its full population
    for i, rungs in rungs_of.items():
        n0 = sum(1 for (j, _t, r) in dispatched if j == i and r == 0)
        assert n0 == sched.jobs[i].plan.sub_automl.n_trials


# ---------------------------------------------------------------------------
# server-level parity: megabatch vs lockstep bucketing on identical seeds
# ---------------------------------------------------------------------------


def test_server_megabatch_matches_lockstep():
    """Acceptance: continuous megabatch and lockstep bucketed dispatch agree
    on winner specs, with trial accuracies within 1e-6, across a fleet with
    mixed rung ladders and mixed shapes."""
    from repro.core.gen_dst import GenDSTConfig

    ladders = ((10, 25), (20,), (10, 25), (15,))
    dims = ((300, 6, 2), (300, 6, 2), (240, 7, 3), (300, 6, 2))
    datasets = [_make(50 + i, *dims[i]) for i in range(4)]
    results = {}
    for mode in (True, False):
        srv = SubStratServer(warm_start=False, megabatch=mode)
        ids = []
        for i, (X, y) in enumerate(datasets):
            pl = plan("gen_dst", cfg=GenDSTConfig(psi=3, phi=8),
                      fine_tune=False,
                      sub_automl=AutoMLConfig(n_trials=5, rungs=ladders[i],
                                              backend="batched"))
            ids.append(srv.submit(X, y, key=jax.random.key(i), plan=pl))
        srv.run()
        results[mode] = [srv.result(j) for j in ids]
        if mode:
            stats = srv.stats()
            assert stats["merged_rungs"] >= 1
            assert stats["mixed_rungs"] >= 1    # genuinely out of lockstep
    for mega, lock in zip(results[True], results[False]):
        assert mega.final.spec == lock.final.spec
        np.testing.assert_array_equal(mega.row_idx, lock.row_idx)
        got = [v for _s, v in mega.final.trials]
        ref = [v for _s, v in lock.final.trials]
        np.testing.assert_allclose(got, ref, atol=1e-6)


def test_plan_opt_out_keeps_lockstep():
    """continuous_batching=False jobs never enter a mixed-rung dispatch."""
    from repro.automl import batched

    log = []
    real = batched.eval_trial_megabatch
    sched = Scheduler()
    for i, ladder in enumerate(((3,), (2, 5))):
        X, y = _make(70 + i, 120, 5, 2)
        pl = plan("random", fine_tune=False, continuous_batching=False,
                  sub_automl=AutoMLConfig(n_trials=3, rungs=ladder,
                                          seed=i, backend="batched"))
        sched.submit(X, y, key=jax.random.key(i), plan=pl)
    batched.eval_trial_megabatch = _fake_eval(log)
    try:
        sched.run()
    finally:
        batched.eval_trial_megabatch = real
    assert log == []                    # nothing rode the megabatch path
    assert all(j.phase == "done" for j in sched.jobs.values())
