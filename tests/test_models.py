"""Per-architecture smoke tests: every assigned arch's REDUCED config runs a
forward + one train step on CPU with correct shapes and finite outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_batch
from repro.models import encdec, lm
from repro.train.optimizer import make_optimizer, warmup_cosine
from repro.train.train_step import init_train_state, make_train_step

ARCH_IDS = sorted(ARCHS)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.smoke
    mod = encdec if cfg.family == "encdec" else lm
    params = mod.init_params(jax.random.key(0), cfg)
    batch = smoke_batch(cfg, batch=2, seq=32)
    logits = mod.forward(params, batch, cfg)
    S_out = batch["labels"].shape[1]
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/inf in logits"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.smoke
    opt = make_optimizer(arch.optimizer, warmup_cosine(1e-3, warmup=2, total=10))
    state = init_train_state(jax.random.key(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, accum_steps=2))
    batch = smoke_batch(cfg, batch=4, seq=32)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The FULL config matches the assigned table (spot checks)."""
    cfg = ARCHS[arch_id].config
    expected = {
        "whisper-base": dict(n_layers=6, d_model=512, n_heads=8, d_ff=2048, vocab_size=51865),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32, d_ff=10240, vocab_size=32000, ssm_state=64),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288, vocab_size=151936),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248, vocab_size=128256),
        "gemma-2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384, vocab_size=256000, head_dim=256),
        "granite-3-2b": dict(n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=49155),
        "phi-3-vision-4.2b": dict(n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32064),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab_size=50280, ssm_state=128),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16, d_ff=1408, vocab_size=151936, n_experts=60, moe_top_k=4),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048, vocab_size=163840, n_experts=384, moe_top_k=8),
    }[arch_id]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch_id}.{k}: {getattr(cfg, k)} != {v}"


def test_registry_complete():
    assert len(ARCHS) == 10
    fams = {a.config.family for a in ARCHS.values()}
    assert fams == {"dense", "encdec", "ssm", "hybrid", "moe", "vlm"}
