"""Heterogeneous-shape cohort merging + batched Gen-DST (DESIGN.md §12.3–4).

The headline assertions are the PR's acceptance criteria: merging
differently-shaped jobs' rung cohorts through maximal-shape padding is
parity-exact with sequential per-job execution (same winner specs, trial
accuracies within 1e-6), and vmapped Gen-DST batches are bit-identical to
solo searches."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.automl.engine import (
    AutoMLConfig, search_eval_rung, search_init, search_record, search_result,
    search_trial_cohort,
)
from repro.automl.batched import eval_rung_cohorts
from repro.automl.models import (
    CLASS_MASK_NEG, FAMILIES, masked_accuracy, masked_fit, masked_loss,
)
from repro.core.gen_dst import GenDSTConfig, gen_dst, gen_dst_batch
from repro.core.measures import factorize
from repro.core.plan import plan
from repro.service import SubStratServer


def _make(seed, N, d, C):
    r = np.random.default_rng(seed)
    y = r.integers(0, C, N)
    X = np.column_stack(
        [y * 1.2 + r.normal(0, 0.8, N) for _ in range(d)]).astype(np.float32)
    return X, y


# three jobs with no two shapes equal: rows, features, AND classes differ
HETERO_JOBS = [((400, 8, 2), 0), ((700, 9, 3), 1), ((250, 6, 2), 2)]


def _solo_and_merged(jobs, n_trials=8, rungs=(10, 25)):
    data = [_make(1 + i, *s) for i, (s, _seed) in enumerate(jobs)]
    cfgs = [AutoMLConfig(n_trials=n_trials, rungs=rungs, seed=seed)
            for (_s, seed) in jobs]

    solos = []
    for (X, y), cfg in zip(data, cfgs):
        st = search_init(X, y, config=cfg)
        while not st.done:
            search_eval_rung(st)
        solos.append(search_result(st))

    states = [search_init(X, y, config=cfg) for (X, y), cfg in zip(data, cfgs)]
    while not all(s.done for s in states):
        live = [s for s in states if not s.done]
        outs = eval_rung_cohorts([search_trial_cohort(s) for s in live])
        for s, (scored, positions) in zip(live, outs):
            search_record(s, scored, positions, 0.0)
    merged = [search_result(s) for s in states]
    return solos, merged


@pytest.fixture(scope="module")
def solo_merged():
    return _solo_and_merged(HETERO_JOBS)


def test_hetero_merge_same_winners(solo_merged):
    solos, merged = solo_merged
    for s, m in zip(solos, merged):
        assert m.spec == s.spec
        assert m.val_acc == pytest.approx(s.val_acc, abs=1e-6)


def test_hetero_merge_trial_accs_within_tolerance(solo_merged):
    """Acceptance: every trial's accuracy within 1e-6 of solo execution."""
    solos, merged = solo_merged
    for s, m in zip(solos, merged):
        assert len(s.trials) == len(m.trials)
        for (spec_s, acc_s), (spec_m, acc_m) in zip(
                sorted(s.trials, key=repr), sorted(m.trials, key=repr)):
            assert spec_s == spec_m
            assert acc_m == pytest.approx(acc_s, abs=1e-6)


def test_hetero_merge_winner_params_unpadded(solo_merged):
    """Winner params come back at the job's own (d, n_classes) shapes."""
    solos, merged = solo_merged
    for (shape, _seed), m in zip(HETERO_JOBS, merged):
        _N, _d, C = shape
        fam = m.spec.family
        leaves = jax.tree.leaves(m.params)
        assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves)
        if fam in ("logreg", "linear_svm"):
            assert m.params["w"].shape[1] == C
        elif fam == "mlp":
            assert m.params["layers"][-1]["w"].shape[1] == C
        elif fam == "gnb":
            assert m.params["mean"].shape[0] == C
        elif fam == "centroid":
            assert m.params["cent"].shape[0] == C


def test_hetero_merge_rejects_mismatched_rungs():
    data = [_make(i, 100, 6, 2) for i in range(2)]
    states = [search_init(X, y, config=AutoMLConfig(n_trials=4, rungs=(10, 20)))
              for X, y in data]
    search_eval_rung(states[0])       # advance one job to rung 1
    with pytest.raises(ValueError, match="rung_i"):
        eval_rung_cohorts([search_trial_cohort(s) for s in states])


# ---------------------------------------------------------------------------
# masked model math: padding is inert
# ---------------------------------------------------------------------------


def _pad_case():
    r = np.random.default_rng(3)
    N, d, C = 40, 5, 3
    X = jnp.asarray(r.normal(0, 1, (N, d)).astype(np.float32))
    y = jnp.asarray(r.integers(0, C, N))
    Xp = jnp.pad(X, ((0, 17), (0, 4)))
    yp = jnp.pad(y, (0, 17))
    w = jnp.pad(jnp.ones(N), (0, 17))
    cmask = jnp.where(jnp.arange(C + 2) < C, 0.0, CLASS_MASK_NEG)
    return X, y, Xp, yp, w, cmask, N, d, C


@pytest.mark.parametrize("family", ["logreg", "linear_svm", "mlp"])
def test_masked_loss_matches_unmasked_on_padded_data(family):
    """Row/class-padded masked loss == unmasked loss on the unpadded data
    (zero-weight rows and masked classes are exactly inert)."""
    X, y, Xp, yp, w, cmask, N, d, C = _pad_case()
    fam = FAMILIES[family]
    hp = {k: v[0] for k, v in fam.hp_grid.items()}
    params = fam.init(jax.random.key(0), d, C, hp)
    # embed params into the padded layout (extra features/classes zero)
    if family == "mlp":
        layers = []
        for i, lyr in enumerate(params["layers"]):
            wpad = ((0, 4), (0, 0)) if i == 0 else ((0, 0), (0, 0))
            if i == len(params["layers"]) - 1:
                wpad = (wpad[0], (0, 2))
            layers.append({"w": jnp.pad(lyr["w"], wpad),
                           "b": jnp.pad(lyr["b"], (0, 2) if
                                        i == len(params["layers"]) - 1 else (0, 0))})
        params_p = {"layers": layers}
    else:
        params_p = {"w": jnp.pad(params["w"], ((0, 4), (0, 2))),
                    "b": jnp.pad(params["b"], (0, 2))}
    ref = fam.loss(params, X, y, C, hp)
    got = masked_loss(family, params_p, Xp, yp, w, cmask, C + 2, hp)
    assert float(got) == pytest.approx(float(ref), rel=1e-5, abs=1e-6)


@pytest.mark.parametrize("family", ["gnb", "centroid"])
def test_masked_fit_matches_unmasked_on_padded_data(family):
    X, y, Xp, yp, w, cmask, N, d, C = _pad_case()
    fam = FAMILIES[family]
    hp = {k: v[0] for k, v in fam.hp_grid.items()}
    ref = fam.fit_closed(None, X, y, C, hp)
    got = masked_fit(family, Xp, yp, w, cmask, C + 2, hp)
    if family == "gnb":
        np.testing.assert_allclose(got["mean"][:C, :d], ref["mean"], atol=1e-5)
        np.testing.assert_allclose(got["prior"][:C], ref["prior"], atol=1e-5)
        assert np.all(np.asarray(got["prior"][C:]) < -1e29)   # masked out
    else:
        np.testing.assert_allclose(got["cent"][:C, :d], ref["cent"], atol=1e-5)
    # masked accuracy on padded val data == plain accuracy on the original
    acc_ref = float((jnp.argmax(fam.predict(ref, X), 1) == y).mean())
    acc_got = float(masked_accuracy(family, got, Xp, yp, w, cmask))
    assert acc_got == pytest.approx(acc_ref, abs=1e-6)


# ---------------------------------------------------------------------------
# batched Gen-DST
# ---------------------------------------------------------------------------


def test_gen_dst_batch_bit_identical_to_solo():
    codeds = [factorize(*_make(10 + i, 300, 6, 2)) for i in range(3)]
    keys = [jax.random.key(i) for i in range(3)]
    cfg = GenDSTConfig(psi=5, phi=8)
    batched = gen_dst_batch(keys, codeds, 15, 3, cfg)
    for k, c, b in zip(keys, codeds, batched):
        solo = gen_dst(k, c, 15, 3, cfg)
        np.testing.assert_array_equal(np.asarray(solo.row_idx),
                                      np.asarray(b.row_idx))
        np.testing.assert_array_equal(np.asarray(solo.col_mask),
                                      np.asarray(b.col_mask))
        assert float(solo.fitness) == float(b.fitness)


def test_gen_dst_batch_rejects_mismatched_shapes():
    a = factorize(*_make(1, 300, 6, 2))
    b = factorize(*_make(2, 200, 6, 2))
    with pytest.raises(ValueError, match="share"):
        gen_dst_batch([jax.random.key(0), jax.random.key(1)], [a, b], 10, 3,
                      GenDSTConfig(psi=2, phi=4))


# ---------------------------------------------------------------------------
# service integration: hetero jobs merge end to end
# ---------------------------------------------------------------------------


SERVE_PLAN = plan("gen_dst", cfg=GenDSTConfig(psi=3, phi=8),
                  sub_automl=AutoMLConfig(n_trials=5, rungs=(15, 40)),
                  ft_automl=AutoMLConfig(n_trials=4, rungs=(40,)))


def test_server_merges_hetero_jobs():
    """Differently-shaped concurrent jobs complete with shape-padded merged
    dispatches, and their results match solo server runs."""
    datasets = [_make(20 + i, *s) for i, (s, _x) in enumerate(HETERO_JOBS)]
    srv = SubStratServer(warm_start=False)
    ids = [srv.submit(X, y, key=jax.random.key(i), plan=SERVE_PLAN)
           for i, (X, y) in enumerate(datasets)]
    srv.run()
    stats = srv.stats()
    assert stats["hetero_rungs"] >= 1
    assert stats["merged_rungs"] >= 1
    for i, jid in enumerate(ids):
        X, y = datasets[i]
        ref_srv = SubStratServer(warm_start=False, hetero_merge=False)
        ref = ref_srv.result(ref_srv.submit(X, y, key=jax.random.key(i),
                                            plan=SERVE_PLAN))
        got = srv.result(jid)
        assert got.final.spec == ref.final.spec
        assert got.final.val_acc == pytest.approx(ref.final.val_acc, abs=1e-6)


def test_server_waste_budget_guards_padding():
    """A fleet of small jobs does not all pad-merge into one big job's
    dispatch: aggregate merge_waste caps each packed group, so most small
    cohorts group among themselves instead of burning ~25x padded compute
    as passengers of the big one."""
    from repro.service.scheduler import CohortMeta, merge_waste

    small = [_make(1 + i, 150, 6, 2) for i in range(7)]
    big = _make(40, 4000, 6, 2)
    srv = SubStratServer(warm_start=False)
    # the all-in-one merge would exceed the budget the scheduler enforces
    metas = [CohortMeta((112, 38, 6, 2), (15,) * 5) for _ in small]
    metas.append(CohortMeta((3000, 1000, 6, 2), (15,) * 5))
    assert merge_waste(metas) > srv.scheduler.waste_budget
    for i, (X, y) in enumerate(small + [big]):
        srv.submit(X, y, key=jax.random.key(i), plan=SERVE_PLAN)
    srv.run()
    stats = srv.stats()
    # the small jobs still merge with each other (same shape, no padding)
    assert stats["merged_rungs"] >= 1
    # but at least one packed group had to exclude the oversized job: with
    # 8 jobs and a respected budget there is more than one dispatch per step
    assert stats["merged_jobs"] < 8 * stats["merged_rungs"]


def test_server_hetero_pad_limit_deprecated():
    """The legacy knob still works but warns, and maps onto waste_budget."""
    with pytest.warns(DeprecationWarning, match="hetero_pad_limit"):
        srv = SubStratServer(hetero_pad_limit=2.5)
    assert srv.scheduler.waste_budget == 2.5
    assert srv.scheduler.hetero_pad_limit == 2.5


def test_server_batched_dst_opt_in():
    """batch_dst=True fuses same-shaped concurrent cache-miss searches and
    produces the same subsets as solo scheduling."""
    datasets = [_make(30 + i, 400, 6, 2) for i in range(3)]
    on = SubStratServer(warm_start=False, batch_dst=True)
    off = SubStratServer(warm_start=False)
    ids_on = [on.submit(X, y, key=jax.random.key(i), plan=SERVE_PLAN)
              for i, (X, y) in enumerate(datasets)]
    ids_off = [off.submit(X, y, key=jax.random.key(i), plan=SERVE_PLAN)
               for i, (X, y) in enumerate(datasets)]
    on.run(), off.run()
    assert on.stats()["merged_dst"] == 3
    assert off.stats()["merged_dst"] == 0
    for a, b in zip(ids_on, ids_off):
        np.testing.assert_array_equal(on.result(a).row_idx,
                                      off.result(b).row_idx)


def test_batch_dst_failure_spares_followers():
    """A failing batched dispatch fails only the searches it ran; duplicate
    submissions (followers) fall back to solo execution and complete."""
    from repro.core.gen_dst import gen_dst
    from repro.core.strategies import STRATEGIES, register_strategy

    def good_fn(key, coded, n, m):
        return gen_dst(key, coded, n, m, GenDSTConfig(psi=2, phi=4))

    def bad_batch(keys, codeds, n, m):
        raise RuntimeError("batch boom")

    register_strategy("fragile_batch", good_fn, batch_fn=bad_batch)
    try:
        p = dataclasses.replace(SERVE_PLAN, strategy="fragile_batch",
                                strategy_opts=())
        (XA, yA), (XB, yB) = _make(50, 300, 6, 2), _make(51, 300, 6, 2)
        srv = SubStratServer(warm_start=False, batch_dst=True)
        a = srv.submit(XA, yA, key=jax.random.key(0), plan=p)
        b = srv.submit(XB, yB, key=jax.random.key(1), plan=p)
        rep = srv.submit(XA, yA, key=jax.random.key(2), plan=p)
        srv.run()
        assert srv.poll(a).phase == "failed" and srv.poll(b).phase == "failed"
        assert srv.poll(rep).done          # follower retried solo
        assert srv.result(rep).final.val_acc is not None
    finally:
        STRATEGIES.pop("fragile_batch", None)


def test_baseline_strategy_served_cached_and_merged():
    """Acceptance: a core/baselines.py strategy runs through the service
    layer with caching (repeat submission hits) and cross-job merging, with
    parity against its direct plan execution."""
    from repro.core.plan import execute
    p = dataclasses.replace(SERVE_PLAN, strategy="ig_km", strategy_opts=())
    X1, y1 = _make(40, 400, 6, 2)
    X2, y2 = _make(41, 400, 6, 2)
    srv = SubStratServer(warm_start=False)
    a = srv.submit(X1, y1, key=jax.random.key(0), plan=p)
    b = srv.submit(X2, y2, key=jax.random.key(1), plan=p)
    rep = srv.submit(X1, y1, key=jax.random.key(2), plan=p)   # repeat of X1
    srv.run()
    stats = srv.stats()
    assert stats["cache"]["hits"] >= 1
    assert srv.poll(rep).cache_hit and not srv.poll(a).cache_hit
    assert stats["merged_rungs"] >= 1
    direct = execute(p, X1, y1, key=jax.random.key(0))
    got = srv.result(a)
    np.testing.assert_array_equal(got.row_idx, direct.row_idx)
    assert got.final.spec == direct.final.spec
    assert got.final.val_acc == pytest.approx(direct.final.val_acc, abs=1e-6)
    assert got.strategy == "ig_km"
