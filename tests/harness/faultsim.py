"""Deterministic fault-injection harness for the serving tier (DESIGN.md
§14.7).

A ``FaultPlan`` names exactly *which worker* fails *at which task* and
*how* — no sleeping and hoping a timer races the scheduler.  Plans compile
to primitive ``(worker, task, action, seconds)`` tuples, the only thing the
runtime layers accept, so workers and examples never import this module:

- ``SimWorkerPool(n, fault_events=plan.compile())`` applies the plan
  in-process, at the same dequeue point as a real worker, with zero timing
  dependence (kills and stalls are bookkeeping, not signals);
- ``ProcessWorkerPool(n, fault_events=plan.compile())`` ships the plan to
  real subprocesses, where ``worker.worker_main`` applies it — ``kill`` is
  a genuine ``os._exit`` mid-protocol;
- ``examples/serve_tabular.py --kill-worker W --kill-task T`` builds the
  same primitives from the CLI for the end-to-end chaos gate in CI.

Actions (see ``repro.service.worker`` for the exact injection point):

- ``kill``  — the worker dies before replying: crash recovery path;
- ``stall`` — the worker goes silent but stays alive: no-beat timeout path;
- ``delay`` — the worker is slow but healthy: must NOT trigger recovery.

``FaultPlan.random(seed, ...)`` derives a reproducible plan from a seed —
the same seed always produces the same kills, which is what makes "chaos
test passes 5/5 runs" a meaningful statement.

This harness is also the supported way for third-party strategies/backends
to test their own code under faults: run your jobs through a
``DistributedScheduler`` over a ``SimWorkerPool`` armed with a plan, and
assert parity against the fault-free run.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Tuple

__all__ = ["FaultEvent", "FaultPlan", "ACTIONS"]

ACTIONS = ("kill", "stall", "delay")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault: ``worker`` misbehaves at its ``task``-th dequeue."""
    worker: int
    task: int
    action: str
    seconds: float = 0.0      # sleep length for stall/delay; unused by kill

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown fault action {self.action!r}; one of {ACTIONS}")
        if self.worker < 0 or self.task < 0:
            raise ValueError("worker and task indices must be >= 0")

    def compile(self) -> Tuple[int, int, str, float]:
        return (self.worker, self.task, self.action, self.seconds)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered set of fault events, compiled for the worker pools."""
    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0             # provenance of random plans (reproducibility)

    def compile(self) -> Tuple[Tuple[int, int, str, float], ...]:
        """The primitive tuples ``ProcessWorkerPool``/``SimWorkerPool``
        (and ``worker.worker_main``) accept as ``fault_events``."""
        return tuple(e.compile() for e in self.events)

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(self.events + other.events, seed=self.seed)

    # -- canned plans --------------------------------------------------------

    @classmethod
    def kill(cls, worker: int, task: int = 0) -> "FaultPlan":
        """Kill ``worker`` the moment it dequeues its ``task``-th task."""
        return cls((FaultEvent(worker, task, "kill"),))

    @classmethod
    def stall(cls, worker: int, task: int = 0,
              seconds: float = 3600.0) -> "FaultPlan":
        """Silence ``worker`` at its ``task``-th task (no beat, no reply)."""
        return cls((FaultEvent(worker, task, "stall", seconds),))

    @classmethod
    def delay(cls, worker: int, task: int = 0,
              seconds: float = 0.1) -> "FaultPlan":
        """Slow ``worker`` down at its ``task``-th task (beats, then runs)."""
        return cls((FaultEvent(worker, task, "delay", seconds),))

    @classmethod
    def random(cls, seed: int, n_workers: int, *, n_events: int = 1,
               max_task: int = 2, actions: Tuple[str, ...] = ("kill",),
               ) -> "FaultPlan":
        """A reproducible plan: the same seed always yields the same faults.

        Each event picks a worker, a task index in ``[0, max_task]``, and
        an action uniformly from ``actions`` using a private ``Random(seed)``
        stream — independent of global RNG state."""
        rng = random.Random(seed)
        events = tuple(
            FaultEvent(rng.randrange(n_workers), rng.randint(0, max_task),
                       rng.choice(list(actions)),
                       seconds=3600.0)
            for _ in range(n_events))
        return cls(events, seed=seed)
