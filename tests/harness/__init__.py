"""Test harnesses shared across the suite (importable as ``harness.*``
because pytest puts ``tests/`` on ``sys.path`` for test modules)."""
