"""Table 4: mean time-reduction and relative-accuracy per method across
datasets (SubStrat vs the baseline DST generators vs Full-AutoML)."""
from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.data.tabular import PAPER_DATASETS
from .common import run_dataset


def main(datasets=("D2", "D3", "D6"), scale=0.2, reps=1, methods=None,
         print_rows=True):
    rows = defaultdict(list)       # method -> [(time_red, rel_acc)]
    for ds in datasets:
        for rep in range(reps):
            full, results = run_dataset(
                PAPER_DATASETS[ds], scale=scale, seed=rep, methods=methods)
            for r in results:
                rows[r.method].append((r.time_reduction, r.relative_accuracy))
            if print_rows:
                print(f"# {ds} rep{rep}: full={full.time_s:.1f}s "
                      f"acc={full.test_acc:.3f}", flush=True)
                for r in results:
                    print(f"#   {r.method:12s} tr={r.time_reduction:+.2%} "
                          f"ra={r.relative_accuracy:.2%}", flush=True)
    table = {}
    for method, vals in rows.items():
        tr = np.array([v[0] for v in vals])
        ra = np.array([v[1] for v in vals])
        table[method] = (tr.mean(), tr.std(), ra.mean(), ra.std())
    return table


if __name__ == "__main__":
    t = main()
    print("method,time_reduction_mean,time_reduction_std,rel_acc_mean,rel_acc_std")
    for m, (trm, trs, ram, ras) in sorted(t.items(), key=lambda kv: -kv[1][2]):
        print(f"{m},{trm:.4f},{trs:.4f},{ram:.4f},{ras:.4f}")
