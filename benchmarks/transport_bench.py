"""Cross-process serving-tier benchmark (DESIGN.md §14): what the wire +
worker-subprocess transport costs relative to the in-process scheduler, how
throughput moves from 1 to 2 workers, and what recovering from a killed
worker adds on top.

The workload is ``n_jobs`` jobs over two synthetic datasets with *distinct
feature counts*, scheduled with ``hetero_merge=False`` so the two shape
groups stay separate dispatches — that gives the pool two concurrent tasks
per step, which is what a second worker can actually absorb.  Budgets are
deliberately tiny: the section measures transport overhead (serialization,
queue hops, worker boot, re-dispatch), not engine throughput, and every
worker subprocess pays its own jit compiles — a real deployment amortizes
those across jobs, so the 1-worker row is dominated by that one-time cost
on this smoke-sized workload.

Rows:

- ``transport_inprocess``   in-process ``Scheduler`` baseline
- ``transport_workers1``    ``ProcessWorkerPool(1)`` — pure wire overhead
- ``transport_workers2``    ``ProcessWorkerPool(2)`` — 2 concurrent tasks
- ``transport_recovery``    ``ProcessWorkerPool(2)`` with worker 0 killed at
  its first task: the front end re-dispatches the orphaned cohorts; derived
  shows the recovery overhead vs the fault-free 2-worker run
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.automl.engine import AutoMLConfig
from repro.core.plan import plan
from repro.service import DistributedScheduler, ProcessWorkerPool, Scheduler

PLAN = plan(
    "gen_dst", n=24, m=4,
    sub_automl=AutoMLConfig(n_trials=6, rungs=(2, 4)),
    ft_automl=AutoMLConfig(n_trials=2, rungs=(2,)),
    psi=4, phi=10,
)


def _make_data(seed: int, N: int, d: int, c: int = 3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, d)).astype(np.float32)
    y = (np.arange(N) % c).astype(np.int64)
    return X, y


def _workload(n_jobs: int, N: int):
    # two distinct feature counts -> two shape groups -> two tasks per step
    datasets = [_make_data(11, N, 6), _make_data(23, N, 10)]
    return [datasets[i % 2] for i in range(n_jobs)]


def _run(jobs, make_scheduler):
    sched = make_scheduler()
    try:
        t0 = time.perf_counter()
        for i, (X, y) in enumerate(jobs):
            sched.submit(X, y, key=jax.random.key(i), plan=PLAN)
        sched.run()
        dt = time.perf_counter() - t0
        return dt, sched.stats()
    finally:
        if hasattr(sched, "close"):
            sched.close()


def transport_rows(n_jobs: int = 4, N: int = 512, quick_tag: str = "quick"):
    """Returns ``(name, us, derived)`` rows for the ``service_transport``
    bench section."""
    jobs = _workload(n_jobs, N)

    # warmup: pay the front end's jit compiles once (workers always pay
    # their own — that cost is part of what this section measures)
    _run(jobs, lambda: Scheduler(hetero_merge=False))
    t_local, _ = _run(jobs, lambda: Scheduler(hetero_merge=False))

    def distributed(n_workers, fault_events=()):
        pool = ProcessWorkerPool(n_workers, fault_events=fault_events)
        return DistributedScheduler(pool, stall_timeout_s=120.0,
                                    hetero_merge=False)

    t_w1, s_w1 = _run(jobs, lambda: distributed(1))
    t_w2, s_w2 = _run(jobs, lambda: distributed(2))
    t_rec, s_rec = _run(jobs, lambda: distributed(2, ((0, 0, "kill", 0.0),)))

    rows = [
        (f"transport_inprocess_{n_jobs}jobs_{quick_tag}", t_local * 1e6,
         f"jobs={n_jobs}"),
        (f"transport_workers1_{n_jobs}jobs_{quick_tag}", t_w1 * 1e6,
         f"overhead={t_w1 / max(t_local, 1e-9):.2f}x "
         f"remote_tasks={s_w1['transport']['remote_tasks']}"),
        (f"transport_workers2_{n_jobs}jobs_{quick_tag}", t_w2 * 1e6,
         f"speedup_vs_1w={t_w1 / max(t_w2, 1e-9):.2f}x "
         f"remote_tasks={s_w2['transport']['remote_tasks']}"),
        (f"transport_recovery_{n_jobs}jobs_{quick_tag}", t_rec * 1e6,
         f"recovery_overhead_s={t_rec - t_w2:.2f} "
         f"worker_failures={s_rec['transport']['worker_failures']} "
         f"redispatched={s_rec['transport']['redispatched_tasks']}"),
    ]
    return rows


if __name__ == "__main__":
    for name, us, derived in transport_rows():
        print(f"{name},{us:.1f},{derived}")
