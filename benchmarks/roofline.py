"""Roofline report: reads experiments/dryrun.json and prints the per-cell
three-term roofline table (EXPERIMENTS.md §Roofline feeds from this)."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun.json"


def load(path=DRYRUN):
    if not Path(path).exists():
        return {}
    return json.loads(Path(path).read_text())


def rows(data=None, mesh="single"):
    data = data if data is not None else load()
    out = []
    for key, v in sorted(data.items()):
        arch, shape, m = key.split("|")
        if m != mesh:
            continue
        if v["status"] != "ok":
            out.append((arch, shape, v["status"], v.get("reason", v.get("error", ""))[:60],
                        None, None, None, None, None, None))
            continue
        r = v["roofline"]
        out.append((
            arch, shape, "ok", r["dominant"],
            r["compute_s"], r["memory_s"], r["collective_s"],
            r["roofline_fraction"], r["useful_flops_ratio"],
            v["memory"]["peak_per_device_gb"],
        ))
    return out


def main():
    print("arch,shape,status,dominant,compute_s,memory_s,collective_s,"
          "roofline_fraction,useful_flops_ratio,peak_gb_per_dev")
    for row in rows():
        arch, shape, status, dom, c, m, coll, frac, useful, peak = row
        if status != "ok":
            print(f"{arch},{shape},{status},{dom},,,,,,")
        else:
            print(f"{arch},{shape},ok,{dom},{c:.4f},{m:.4f},{coll:.4f},"
                  f"{frac:.4f},{useful:.3f},{peak:.2f}")


if __name__ == "__main__":
    main()
