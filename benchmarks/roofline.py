"""Roofline report: reads experiments/dryrun.json and prints the per-cell
three-term roofline table (EXPERIMENTS.md §Roofline feeds from this)."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun.json"


def load(path=DRYRUN):
    if not Path(path).exists():
        return {}
    return json.loads(Path(path).read_text())


def rows(data=None, mesh="single"):
    data = data if data is not None else load()
    out = []
    for key, v in sorted(data.items()):
        arch, shape, m = key.split("|")
        if m != mesh:
            continue
        if v["status"] != "ok":
            out.append((arch, shape, v["status"], v.get("reason", v.get("error", ""))[:60],
                        None, None, None, None, None, None))
            continue
        r = v["roofline"]
        out.append((
            arch, shape, "ok", r["dominant"],
            r["compute_s"], r["memory_s"], r["collective_s"],
            r["roofline_fraction"], r["useful_flops_ratio"],
            v["memory"]["peak_per_device_gb"],
        ))
    return out


# representative Gen-DST regimes for the analytic fused-generation roofline:
# the paper-default population on a 100k×23 dataset, and the quick-bench one
GEN_DST_SHAPES = [
    # (phi, n, M, B)
    (100, 316, 23, 256),   # paper default: phi=100, n=sqrt(100k)
    (16, 141, 9, 256),     # quick-bench regime (benchmarks/kernels_bench.py)
]


def gen_dst_rows(shapes=None, tile_p=8):
    """Analytic roofline rows for the fused Gen-DST generation kernel
    (DESIGN.md §16.5), same 10-column layout as the dry-run rows.

    Unlike the model cells these don't come from compiled HLO — the kernel's
    FLOPs and HBM traffic are closed-form: ``launch/flops.py`` prices the
    launched vs useful work, and the memory term is one read + one write of
    the padded (phi, M, B) count tensor plus the per-candidate row codes and
    masks.  ``collective_s`` is 0 (single-chip launch)."""
    from repro.launch.dryrun import HBM_BW, PEAK_FLOPS
    from repro.launch.flops import gen_dst_generation_flops

    out = []
    for phi, n, M, B in shapes or GEN_DST_SHAPES:
        phi_p = -(-phi // tile_p) * tile_p
        counts_bytes = phi_p * M * B * 4.0
        side_bytes = phi_p * (3 * M * 4.0 + M * 4.0 + 8.0)  # codes/mask/w/fit
        for mode in ("delta", "full"):
            useful, launched = gen_dst_generation_flops(
                phi, n, M, B, mode=mode, tile_p=tile_p)
            bytes_dev = 2.0 * counts_bytes + side_bytes
            if mode == "full":   # rebuild also streams the gathered rows
                bytes_dev += phi_p * n * M * 4.0
            t_compute = launched / PEAK_FLOPS
            t_memory = bytes_dev / HBM_BW
            dominant = "compute" if t_compute >= t_memory else "memory"
            bound = max(t_compute, t_memory)
            out.append((
                "gen_dst_fused", f"{mode}_phi{phi}_n{n}_M{M}_B{B}", "ok",
                dominant, t_compute, t_memory, 0.0,
                (useful / PEAK_FLOPS) / max(bound, 1e-12),
                useful / launched, counts_bytes / 1e9,
            ))
    return out


def main():
    print("arch,shape,status,dominant,compute_s,memory_s,collective_s,"
          "roofline_fraction,useful_flops_ratio,peak_gb_per_dev")
    for row in rows() + gen_dst_rows():
        arch, shape, status, dom, c, m, coll, frac, useful, peak = row
        if status != "ok":
            print(f"{arch},{shape},{status},{dom},,,,,,")
        else:
            print(f"{arch},{shape},ok,{dom},{c:.4f},{m:.4f},{coll:.4f},"
                  f"{frac:.4f},{useful:.3f},{peak:.2f}")


if __name__ == "__main__":
    main()
