"""Figure 3: SubStrat configuration skyline — different (psi, phi, DST-size)
settings trade time-reduction against relative accuracy."""
from __future__ import annotations

import numpy as np

from repro.core.gen_dst import GenDSTConfig
from repro.data.tabular import PAPER_DATASETS
from .common import run_dataset, substrat_config

SETTINGS = {
    "SubStrat-default": substrat_config(),
    "SubStrat-fast": substrat_config(gen=GenDSTConfig(psi=4, phi=12)),
    "SubStrat-thorough": substrat_config(gen=GenDSTConfig(psi=20, phi=40)),
    "SubStrat-wide": substrat_config(m=None, n=None),  # default sizes
}


def main(dataset="D3", scale=0.2):
    spec = PAPER_DATASETS[dataset]
    points = []
    for name, cfg in SETTINGS.items():
        _, results = run_dataset(spec, scale=scale, methods=["SubStrat"],
                                 sub_cfg=cfg)
        r = results[0]
        points.append((name, r.time_reduction, r.relative_accuracy))
    # skyline: drop strictly-dominated configs
    skyline = [p for p in points
               if not any(q[1] >= p[1] and q[2] >= p[2] and q != p for q in points)]
    return points, skyline


if __name__ == "__main__":
    points, skyline = main()
    print("setting,time_reduction,relative_accuracy,on_skyline")
    for name, tr, ra in points:
        print(f"{name},{tr:.4f},{ra:.4f},{(name, tr, ra) in skyline}")
