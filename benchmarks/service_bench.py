"""Service-layer benchmark (DESIGN.md §11): job throughput of the
multi-tenant server vs sequential ``substrat()`` calls, and the Gen-DST
time a repeat submission's cache hit skips.

The workload is 8 jobs over 2 distinct datasets (4 submissions each) — the
serving pattern the layer targets: repeated AutoML runs on recurring data.
Sequential execution pays factorize + Gen-DST + sub-AutoML + fine-tune per
job; the server fingerprints each dataset (2 Gen-DST runs total, 6 cache
hits), parks concurrent repeats in ``warm_wait`` so they skip the
sub-AutoML pass and warm-start the restricted fine-tune, and merges
concurrent jobs' rung cohorts into single batched dispatches.  Job budgets
are the shared quick-mode configuration from ``benchmarks.common``.  One
untimed warmup pass amortizes jit compilation for both sides, mirroring
``automl_bench``.

Acceptance targets (ISSUE 3): >= 3x throughput at 8 concurrent jobs;
cache hits skip >= 90% of the Gen-DST phase time.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.substrat import substrat
from repro.service import SubStratServer

from .common import substrat_config


def _make_data(seed: int, N: int, d: int):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, N)
    X = np.column_stack(
        [y * 1.5 + rng.normal(0, 0.8, N) for _ in range(d)]).astype(np.float32)
    return X, y


def _workload(n_jobs: int, N: int, d: int):
    """n_jobs submissions cycling over 2 distinct datasets."""
    datasets = [_make_data(11, N, d), _make_data(23, N, d)]
    return [datasets[i % 2] for i in range(n_jobs)]


def service_rows(n_jobs: int = 8, N: int = 2_000, d: int = 10, quick_tag: str = "2k"):
    """Returns ``(name, us, derived)`` rows for the ``service`` bench section.

    Job budgets are the shared quick-mode SubStrat configuration
    (``benchmarks.common.substrat_config``) — the same engine budgets every
    other quick-mode section runs."""
    cfg = substrat_config()
    jobs = _workload(n_jobs, N, d)

    def run_sequential():
        t0 = time.perf_counter()
        results = [substrat(X, y, key=jax.random.key(i), config=cfg)
                   for i, (X, y) in enumerate(jobs)]
        return time.perf_counter() - t0, results

    def run_service():
        srv = SubStratServer()
        t0 = time.perf_counter()
        ids = [srv.submit(X, y, key=jax.random.key(i), config=cfg)
               for i, (X, y) in enumerate(jobs)]
        srv.run()
        return time.perf_counter() - t0, srv, ids

    run_sequential()                      # warmup: pay jit compiles
    run_service()
    t_seq, _ = run_sequential()
    t_srv, srv, ids = run_service()

    stats = srv.stats()
    rows = [
        (f"service_sequential_{n_jobs}jobs_{quick_tag}", t_seq * 1e6,
         f"jobs={n_jobs}"),
        (f"service_concurrent_{n_jobs}jobs_{quick_tag}", t_srv * 1e6,
         f"speedup={t_seq / t_srv:.2f}x merged_rungs={stats['merged_rungs']} "
         f"merged_jobs={stats['merged_jobs']} "
         f"cache_hits={stats['cache']['hits']}"),
    ]

    # cache-hit DST skip: first submission of a dataset pays Gen-DST, its
    # repeat pays a cache lookup
    miss = srv.poll(ids[0]).times["gen_dst_s"]
    hit = srv.poll(ids[2]).times["gen_dst_s"]    # same dataset as ids[0]
    rows.append((
        f"service_dst_cache_hit_{quick_tag}", hit * 1e6,
        f"miss_us={miss * 1e6:.1f} skip={1.0 - hit / max(miss, 1e-12):.3%}",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in service_rows():
        print(f"{name},{us:.1f},{derived}")
