"""Heterogeneous-merge benchmark (DESIGN.md §12.3–§12.4): throughput of the
two cross-job merge layers the plan API unlocked.

- **hetero rung merge**: 2–4 concurrent searches on *differently-shaped*
  data (different ``(N_tr, d, n_classes)`` — no two shapes equal, so
  pre-§12 none of them could share a dispatch) advanced rung-by-rung merged
  (``eval_rung_cohorts`` shape-padding path, one fused program per rung)
  vs sequentially (one ``search_eval_rung`` program per job per rung).
  The workload is the serving sub-AutoML regime the merge targets:
  DST-sized data (~100 rows — a sqrt(N) subset of a paper-scale dataset),
  small per-tenant trial budgets, closely-clustered shapes (the scheduler's
  ``hetero_pad_limit`` admits exactly this cluster-shaped traffic; widely
  spread shapes run per-shape instead because padding waste would dominate).
  Acceptance target (ISSUE 5): >= 1.5x throughput at 4 jobs.

- **batched Gen-DST**: K same-shaped (distinct-content) datasets searched by
  one vmapped ``gen_dst_batch`` dispatch vs K sequential ``gen_dst`` calls —
  the scheduler's cache-miss fusion path, bit-identical per search.  On one
  CPU core this is a wash (the GA is already a single fused scan with no
  dispatch overhead to amortize; the row records the measured ratio) — it
  is a device-utilization play: on parallel hardware K small independent
  searches underfill the device and the vmapped batch fills it.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.automl.engine import (
    AutoMLConfig, search_eval_rung, search_init, search_record,
    search_trial_cohort,
)
from repro.automl.batched import eval_rung_cohorts
from repro.core.gen_dst import GenDSTConfig, gen_dst, gen_dst_batch
from repro.core.measures import factorize

# 4 deliberately different job shapes: rows / features / classes all vary,
# clustered the way the scheduler's pad-waste guard admits
_SHAPES = [(100, 8, 2), (105, 8, 3), (110, 8, 2), (95, 9, 2)]


def _make_data(seed: int, N: int, d: int, C: int):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, C, N)
    X = np.column_stack(
        [y * 1.2 + rng.normal(0, 0.8, N) for _ in range(d)]).astype(np.float32)
    return X, y


def _measure(fn, reps: int = 5) -> float:
    fn()                                  # warmup: pay jit compiles
    return min(fn() for _ in range(reps))


def hetero_rows(n_jobs: int = 4, quick_tag: str = "quick"):
    """Returns ``(name, us, derived)`` rows for the ``hetero_merge`` section."""
    shapes = _SHAPES[:n_jobs]
    data = [_make_data(7 + i, *s) for i, s in enumerate(shapes)]
    cfgs = [AutoMLConfig(n_trials=6, rungs=(20, 60), seed=i)
            for i in range(n_jobs)]

    def run_sequential():
        t0 = time.perf_counter()
        for (X, y), cfg in zip(data, cfgs):
            st = search_init(X, y, config=cfg)
            while not st.done:
                search_eval_rung(st)
        return time.perf_counter() - t0

    def run_merged():
        t0 = time.perf_counter()
        states = [search_init(X, y, config=cfg)
                  for (X, y), cfg in zip(data, cfgs)]
        while not all(s.done for s in states):
            live = [s for s in states if not s.done]
            outs = eval_rung_cohorts([search_trial_cohort(s) for s in live])
            for s, (scored, positions) in zip(live, outs):
                search_record(s, scored, positions, 0.0)
        return time.perf_counter() - t0

    t_seq = _measure(run_sequential)
    t_merged = _measure(run_merged)
    rows = [
        (f"hetero_sequential_{n_jobs}jobs_{quick_tag}", t_seq * 1e6,
         f"dispatches_per_rung={n_jobs}"),
        (f"hetero_merged_{n_jobs}jobs_{quick_tag}", t_merged * 1e6,
         f"speedup={t_seq / t_merged:.2f}x dispatches_per_rung=1 "
         f"shapes={'/'.join(str(s) for s in shapes)}"),
    ]

    # batched Gen-DST: K same-shaped, distinct-content datasets
    K = 4
    codeds = [factorize(*_make_data(100 + i, 2_000, 8, 2)) for i in range(K)]
    keys = [jax.random.key(i) for i in range(K)]
    cfg = GenDSTConfig(psi=8, phi=24)
    n, m = 45, 3

    def run_dst_seq():
        t0 = time.perf_counter()
        outs = [gen_dst(k, c, n, m, cfg) for k, c in zip(keys, codeds)]
        jax.block_until_ready([o.row_idx for o in outs])
        return time.perf_counter() - t0

    def run_dst_batch():
        t0 = time.perf_counter()
        outs = gen_dst_batch(keys, codeds, n, m, cfg)
        jax.block_until_ready([o.row_idx for o in outs])
        return time.perf_counter() - t0

    t_dseq = _measure(run_dst_seq, reps=3)
    t_dbatch = _measure(run_dst_batch, reps=3)
    rows.append((
        f"gen_dst_batch_{K}jobs_{quick_tag}", t_dbatch * 1e6,
        f"sequential_us={t_dseq * 1e6:.1f} speedup={t_dseq / t_dbatch:.2f}x "
        f"(device-utilization play; ~neutral on 1 CPU core)",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in hetero_rows():
        print(f"{name},{us:.1f},{derived}")
