"""Figure 5: isolated effect of DST length (n, with m=0.25M) and width
(m, with n=sqrt(N))."""
from __future__ import annotations

import numpy as np

from repro.data.tabular import PAPER_DATASETS, make_dataset
from .common import run_dataset, substrat_config


def main(dataset="D3", scale=0.2):
    spec = PAPER_DATASETS[dataset]
    X, _ = make_dataset(spec, scale=scale)
    N, M = X.shape
    length_points, width_points = [], []
    for n in (int(np.log2(N)), int(N ** 0.5), int(N ** 0.7), int(N ** 0.85)):
        _, res = run_dataset(spec, scale=scale, methods=["SubStrat"],
                             sub_cfg=substrat_config(n=n))
        length_points.append((n, res[0].time_reduction, res[0].relative_accuracy))
    for m in (2, max(2, int(0.25 * M)), max(3, int(0.5 * M)), M):
        _, res = run_dataset(spec, scale=scale, methods=["SubStrat"],
                             sub_cfg=substrat_config(m=m))
        width_points.append((m, res[0].time_reduction, res[0].relative_accuracy))
    return length_points, width_points


if __name__ == "__main__":
    lp, wp = main()
    print("axis,value,time_reduction,relative_accuracy")
    for n, tr, ra in lp:
        print(f"n,{n},{tr:.4f},{ra:.4f}")
    for m, tr, ra in wp:
        print(f"m,{m},{tr:.4f},{ra:.4f}")
