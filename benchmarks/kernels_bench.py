"""Kernel micro-benchmarks + Gen-DST convergence timing (paper §3.3).

Times the XLA reference paths (the production CPU-measurable numbers) and
validates the Pallas kernels in interpret mode.  On a real TPU the Pallas
paths are enabled with use_pallas=True, interpret=False.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gen_dst import GenDSTConfig, gen_dst
from repro.core.measures import factorize
from repro.kernels.entropy.ref import masked_histogram_ref
from repro.kernels.entropy.kernel import masked_histogram_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main():
    rows = []
    rng = np.random.default_rng(0)

    # masked histogram (Gen-DST fitness primitive)
    for N, M, B in [(10_000, 23, 256), (100_000, 23, 256), (1_000_000, 15, 256)]:
        codes = jnp.asarray(rng.integers(0, B, (N, M)), jnp.int32)
        w = jnp.asarray((rng.random(N) < 0.01).astype(np.float32))
        us = _time(lambda c, ww: masked_histogram_ref(c, ww, B), codes, w)
        rows.append((f"masked_hist_ref_{N}x{M}", us, f"{N*M/us:.0f} cells/us"))

    # pallas kernel correctness spot (interpret mode, small)
    codes = jnp.asarray(rng.integers(0, 64, (2048, 8)), jnp.int32)
    w = jnp.ones((2048,), jnp.float32)
    t0 = time.perf_counter()
    hk = masked_histogram_pallas(codes, w, 64)
    hr = masked_histogram_ref(codes, w, 64)
    ok = bool(jnp.abs(hk - hr).max() < 1e-3)
    rows.append(("masked_hist_pallas_interp_ok", (time.perf_counter() - t0) * 1e6,
                 f"allclose={ok}"))

    # Gen-DST end-to-end (paper default config on a 100k-row dataset)
    X = np.column_stack([rng.integers(0, k, 100_000)
                         for k in (3, 5, 17, 2, 40, 7, 200, 11)]).astype(float)
    y = rng.integers(0, 2, 100_000).astype(float)
    coded = factorize(X, y)
    t0 = time.perf_counter()
    res = gen_dst(jax.random.key(0), coded, cfg=GenDSTConfig(psi=30, phi=100))
    jax.block_until_ready(res.fitness)
    t_total = time.perf_counter() - t0
    rows.append(("gen_dst_100k_default", t_total * 1e6,
                 f"loss={-float(res.fitness):.5f}"))
    # steady-state (post-compile) generation rate
    t0 = time.perf_counter()
    res = gen_dst(jax.random.key(1), coded, cfg=GenDSTConfig(psi=30, phi=100))
    jax.block_until_ready(res.fitness)
    rows.append(("gen_dst_100k_steady", (time.perf_counter() - t0) * 1e6,
                 f"{30 / max(time.perf_counter() - t0, 1e-9):.1f} gen/s"))

    # attention reference (XLA path used in the dry-run)
    q = jnp.asarray(rng.normal(0, 1, (1, 1024, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (1, 1024, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (1, 1024, 2, 64)), jnp.bfloat16)
    us = _time(lambda a, b, c: attention_ref(a, b, c, causal=True), q, k, v)
    flops = 4 * 1024 * 1024 * 8 * 64 / 2
    rows.append(("attention_ref_1k_gqa", us, f"{flops/us/1e6:.1f} GFLOP/s"))

    return rows


def gen_dst_rows(N=100_000, psi=24, phi=100, cross_every=4, quick_tag="100k"):
    """Generation-step timing: incremental fitness vs full recompute, islands.

    The incremental-vs-full pair shares the GA trajectory bit-for-bit (same
    key, same cadence), so the speedup isolates exactly the fitness-path
    change (DESIGN.md §5.5).  Acceptance target: >=2x at N>=100k.
    """
    rng = np.random.default_rng(0)
    X = np.column_stack([rng.integers(0, k, N)
                         for k in (3, 5, 17, 2, 40, 7, 200, 11)]).astype(float)
    y = rng.integers(0, 2, N).astype(float)
    coded = factorize(X, y)

    def run(cfg, key=1):
        res = gen_dst(jax.random.key(0), coded, cfg=cfg)   # warmup/compile
        jax.block_until_ready(res.fitness)
        t0 = time.perf_counter()
        res = gen_dst(jax.random.key(key), coded, cfg=cfg)
        jax.block_until_ready(res.fitness)
        return (time.perf_counter() - t0) / cfg.psi * 1e6, res  # us/generation

    rows = []
    cfg = GenDSTConfig(psi=psi, phi=phi, cross_every=cross_every)
    us_full, r_full = run(cfg._replace(incremental=False))
    us_inc, r_inc = run(cfg)
    assert float(r_full.fitness) == float(r_inc.fitness), "parity broken"
    rows.append((f"gen_dst_step_full_{quick_tag}", us_full,
                 f"loss={-float(r_full.fitness):.5f}"))
    rows.append((f"gen_dst_step_incremental_{quick_tag}", us_inc,
                 f"speedup={us_full / us_inc:.2f}x"))

    isl = GenDSTConfig(psi=psi, phi=max(2, phi // 4) // 2 * 2, num_islands=4,
                       migrate_every=5, cross_every=cross_every)
    us_isl, r_isl = run(isl)
    rows.append((f"gen_dst_step_islands4_{quick_tag}", us_isl,
                 f"loss={-float(r_isl.fitness):.5f}"))
    return rows


def gen_dst_fused_rows(N=20_000, psi=6, phi=16, quick_tag="20k"):
    """Per-generation timing of the fused backend (DESIGN.md §16).

    Two regimes, each timed for ``backend="jnp"`` and ``"pallas_fused"``
    with the same key so the trajectories are bit-identical (asserted):
    ``delta`` (cross_every=4 — 3 of 4 generations are one-row delta
    updates) and ``full`` (cross_every=1 — every generation rebuilds the
    histograms).  On CPU the Pallas leg runs in *interpret mode*: the
    timing validates semantics and recompile hygiene, not speed — the
    compiled number needs a real TPU.  The derived column carries the
    analytic useful/launched FLOPs ratio (``launch/flops.py``), the
    padding+one-hot-materialization overhead a TPU roofline would see.
    """
    from repro.launch.flops import gen_dst_generation_flops

    rng = np.random.default_rng(0)
    X = np.column_stack([rng.integers(0, k, N)
                         for k in (3, 5, 17, 2, 40, 7, 200, 11)]).astype(float)
    y = rng.integers(0, 2, N).astype(float)
    coded = factorize(X, y)
    n = max(2, int(round(N ** 0.5)))
    M, B = coded.codes.shape[1], coded.max_bins

    def run(cfg):
        res = gen_dst(jax.random.key(0), coded, cfg=cfg)   # warmup/compile
        jax.block_until_ready(res.fitness)
        t0 = time.perf_counter()
        res = gen_dst(jax.random.key(2), coded, cfg=cfg)
        jax.block_until_ready(res.fitness)
        return (time.perf_counter() - t0) / cfg.psi * 1e6, res

    rows = []
    for mode, cross_every in (("delta", 4), ("full", 1)):
        cfg = GenDSTConfig(psi=psi, phi=phi, cross_every=cross_every)
        us_jnp, r_jnp = run(cfg._replace(backend="jnp"))
        us_fused, r_fused = run(cfg._replace(backend="pallas_fused"))
        assert float(r_jnp.fitness) == float(r_fused.fitness), \
            f"fused backend parity broken ({mode})"
        useful, launched = gen_dst_generation_flops(phi, n, M, B, mode=mode)
        rows.append((f"gen_dst_gen_jnp_{mode}_{quick_tag}", us_jnp,
                     f"loss={-float(r_jnp.fitness):.5f}"))
        rows.append((f"gen_dst_gen_fused_{mode}_{quick_tag}", us_fused,
                     f"useful/launched={useful / launched:.3f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
    for name, us, derived in gen_dst_rows(N=20_000, psi=12, quick_tag="20k"):
        print(f"{name},{us:.1f},{derived}")
    for name, us, derived in gen_dst_fused_rows(N=20_000, quick_tag="20k"):
        print(f"{name},{us:.1f},{derived}")
