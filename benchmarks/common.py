"""Shared benchmark harness: run Full-AutoML vs SubStrat vs baselines on a
dataset and report the paper's metrics (time-reduction, relative-accuracy).

Every method is a declarative ``Plan`` (DESIGN.md §12) executed by the one
shared driver: SubStrat is ``plan("gen_dst")``, the paper baselines are the
same plan with a different SubsetStrategy, and ASP is the proxy-scorer
strategy — the harness itself is a thin client of ``plan()``/``execute()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax

from repro.automl.engine import AutoMLConfig, automl_fit
from repro.core.gen_dst import GenDSTConfig
from repro.core.measures import factorize
from repro.core.plan import Plan, execute, plan_from_config
from repro.core.substrat import SubStratConfig
from repro.core.strategies import run_strategy
from repro.data.tabular import DatasetSpec, make_dataset, train_test_split

# quick-mode engine budgets (scaled so compute, not jit, dominates on CPU)
QUICK_AUTOML = AutoMLConfig(n_trials=10, rungs=(60, 200))
QUICK_FT = AutoMLConfig(n_trials=4, rungs=(120,))
QUICK_GEN = GenDSTConfig(psi=10, phi=24)


def substrat_config(**kw) -> SubStratConfig:
    base = dict(gen=QUICK_GEN, sub_automl=QUICK_AUTOML, ft_automl=QUICK_FT)
    base.update(kw)
    return SubStratConfig(**base)


# method name -> (strategy, strategy_opts): the subset axis of each plan
BASELINE_STRATEGIES: Dict[str, Tuple[str, tuple]] = {
    "MC-100": ("mc", (("budget", 100), ("batch", 50))),
    "MC-100K": ("mc", (("budget", 4000), ("batch", 200))),
    "MAB": ("mab", (("rounds", 200),)),
    "KM": ("km", ()),
    "IG-Rand": ("ig_rand", ()),
    "IG-KM": ("ig_km", ()),
    "ASP": ("asp_proxy", ()),
}


def method_plan(method: str, sub_cfg: SubStratConfig) -> Plan:
    """The ``Plan`` of one named method under the shared engine budgets."""
    base = plan_from_config(sub_cfg)
    if method == "SubStrat":
        return base
    if method == "SubStrat-NF":
        return dataclasses.replace(base, fine_tune=False)
    strategy, opts = BASELINE_STRATEGIES[method]
    return dataclasses.replace(base, strategy=strategy, strategy_opts=opts)


@dataclasses.dataclass
class BenchResult:
    dataset: str
    method: str
    time_s: float
    test_acc: float
    time_reduction: float
    relative_accuracy: float


def run_dataset(
    spec: DatasetSpec,
    *,
    scale: float = 0.05,
    seed: int = 0,
    methods: Optional[list] = None,
    sub_cfg: Optional[SubStratConfig] = None,
    full_cfg: AutoMLConfig = QUICK_AUTOML,
):
    """Returns (full BenchResult, [method BenchResults])."""
    X, y = make_dataset(spec, scale=scale)
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.2, seed=seed)
    coded = factorize(Xtr, ytr)     # shared across methods (like the paper's
                                    # one-time preprocessing)
    t0 = time.perf_counter()
    full = automl_fit(Xtr, ytr, config=full_cfg, X_test=Xte, y_test=yte)
    t_full = time.perf_counter() - t0
    full_res = BenchResult(spec.name, "Full-AutoML", t_full, full.test_acc, 0.0, 1.0)

    sub_cfg = sub_cfg or substrat_config()
    out = []
    methods = methods if methods is not None else (
        ["SubStrat", "SubStrat-NF"] + list(BASELINE_STRATEGIES)
    )
    # warm up the subset strategies once (untimed): jit compilation is a
    # one-time per-(shape, config) cost a production deployment amortizes
    # across runs; the paper's sklearn stack has no analogous cost.  The
    # AutoML engine's compiles hit Full-AutoML and SubStrat equally and are
    # left in the timings.
    for method in set(methods):
        p = method_plan(method, sub_cfg)
        run_strategy(p.strategy, jax.random.key(0), coded, p.n, p.m,
                     p.strategy_opts)
    for method in methods:
        key = jax.random.key(seed * 977 + 13)
        res = execute(method_plan(method, sub_cfg), Xtr, ytr, key=key,
                      coded=coded, X_test=Xte, y_test=yte)
        t = res.total_time_s
        acc = res.final.test_acc
        out.append(BenchResult(
            spec.name, method, t, acc,
            1.0 - t / max(t_full, 1e-9), acc / max(full.test_acc, 1e-9),
        ))
    return full_res, out
