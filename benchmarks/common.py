"""Shared benchmark harness: run Full-AutoML vs SubStrat vs baselines on a
dataset and report the paper's metrics (time-reduction, relative-accuracy).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.automl.engine import AutoMLConfig, automl_fit
from repro.core.baselines import (
    ig_km_dst, ig_rand_dst, km_dst, mab_dst, mc_dst,
)
from repro.core.gen_dst import GenDSTConfig
from repro.core.measures import factorize
from repro.core.substrat import SubStratConfig, substrat
from repro.data.tabular import DatasetSpec, make_dataset, train_test_split

# quick-mode engine budgets (scaled so compute, not jit, dominates on CPU)
QUICK_AUTOML = AutoMLConfig(n_trials=10, rungs=(60, 200))
QUICK_FT = AutoMLConfig(n_trials=4, rungs=(120,))
QUICK_GEN = GenDSTConfig(psi=10, phi=24)


def substrat_config(**kw) -> SubStratConfig:
    base = dict(gen=QUICK_GEN, sub_automl=QUICK_AUTOML, ft_automl=QUICK_FT)
    base.update(kw)
    return SubStratConfig(**base)


BASELINE_DST_FNS: Dict[str, Callable] = {
    "MC-100": lambda k, c, n, m: mc_dst(k, c, n, m, budget=100, batch=50),
    "MC-100K": lambda k, c, n, m: mc_dst(k, c, n, m, budget=4000, batch=200),
    "MAB": lambda k, c, n, m: mab_dst(k, c, n, m, rounds=200),
    "KM": km_dst,
    "IG-Rand": ig_rand_dst,
    "IG-KM": ig_km_dst,
}


@dataclasses.dataclass
class BenchResult:
    dataset: str
    method: str
    time_s: float
    test_acc: float
    time_reduction: float
    relative_accuracy: float


def run_dataset(
    spec: DatasetSpec,
    *,
    scale: float = 0.05,
    seed: int = 0,
    methods: Optional[list] = None,
    sub_cfg: Optional[SubStratConfig] = None,
    full_cfg: AutoMLConfig = QUICK_AUTOML,
):
    """Returns (full BenchResult, [method BenchResults])."""
    X, y = make_dataset(spec, scale=scale)
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.2, seed=seed)
    coded = factorize(Xtr, ytr)     # shared across methods (like the paper's
                                    # one-time preprocessing)
    t0 = time.perf_counter()
    full = automl_fit(Xtr, ytr, config=full_cfg, X_test=Xte, y_test=yte)
    t_full = time.perf_counter() - t0
    full_res = BenchResult(spec.name, "Full-AutoML", t_full, full.test_acc, 0.0, 1.0)

    sub_cfg = sub_cfg or substrat_config()
    out = []
    methods = methods if methods is not None else (
        ["SubStrat", "SubStrat-NF"] + list(BASELINE_DST_FNS)
    )
    # warm up the DST generators once (untimed): jit compilation is a
    # one-time per-(shape, config) cost a production deployment amortizes
    # across runs; the paper's sklearn stack has no analogous cost.  The
    # AutoML engine's compiles hit Full-AutoML and SubStrat equally and are
    # left in the timings.
    from repro.core.gen_dst import gen_dst as _gd
    for method in set(methods):
        if method in ("SubStrat", "SubStrat-NF"):
            _gd(jax.random.key(0), coded, sub_cfg.n, sub_cfg.m, sub_cfg.gen)
        elif method in BASELINE_DST_FNS:
            BASELINE_DST_FNS[method](jax.random.key(0), coded, None, None)
    for method in methods:
        key = jax.random.key(seed * 977 + 13)
        if method == "SubStrat":
            res = substrat(Xtr, ytr, key=key, config=sub_cfg, coded=coded,
                           X_test=Xte, y_test=yte)
        elif method == "SubStrat-NF":
            cfg_nf = dataclasses.replace(sub_cfg, fine_tune=False)
            res = substrat(Xtr, ytr, key=key, config=cfg_nf, coded=coded,
                           X_test=Xte, y_test=yte)
        else:
            res = substrat(Xtr, ytr, key=key, config=sub_cfg, coded=coded,
                           dst_fn=BASELINE_DST_FNS[method],
                           X_test=Xte, y_test=yte)
        t = res.total_time_s
        acc = res.final.test_acc
        out.append(BenchResult(
            spec.name, method, t, acc,
            1.0 - t / max(t_full, 1e-9), acc / max(full.test_acc, 1e-9),
        ))
    return full_res, out
