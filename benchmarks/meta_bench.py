"""Cross-tenant meta-learning benchmark (DESIGN.md §17): trials to reach
the cold baseline's winner accuracy, cold vs portfolio-warm-started.

Protocol:

1. **History** — serve ``n_history`` distinct synthetic datasets on one
   scheduler, populating its experience store.
2. **Cold** — a fresh scheduler (empty store) serves ``n_eval`` *new*
   distinct datasets with ``Plan(warm_start=False)``; per job, record the
   sub-AutoML pass's dispatched-trial count and the trial index at which
   the winner's validation accuracy was first reached.
3. **Warm** — another fresh scheduler, its store restored from the history
   run's ``state_dict()`` (exercising the persistence path), serves the
   same datasets warm-started; count the dispatched trials until each job
   first reaches its cold winner accuracy (within 1e-6).

The section asserts the ISSUE acceptance bar inline so CI's bench-smoke
run enforces it: every warm job reaches its cold winner accuracy, every
warm pass is portfolio-seeded, and warm dispatches <= 0.75x the cold
trial count in total.  Everything is seeded — the verdict is
deterministic, not a timing race.
"""
from __future__ import annotations

import time

import numpy as np

from repro.automl.engine import AutoMLConfig
from repro.core.plan import plan
from repro.meta import ExperienceStore
from repro.service.scheduler import Scheduler


def _make_data(seed: int, N: int, d: int):
    """One distinct-fingerprint synthetic binary task per seed."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, N)
    X = np.column_stack([y * 1.5 + rng.normal(0, 0.8, N) for _ in range(d)])
    return X, y


def _trials_to_reach(result, target_acc: float):
    """Index (1-based) of the first logged trial scoring >= target - 1e-6,
    or None if the search never reached it."""
    for i, (_spec, acc) in enumerate(result.trials):
        if float(acc) >= target_acc - 1e-6:
            return i + 1
    return None


def _serve(scheduler: Scheduler, datasets, p):
    """Submit every dataset, drive to completion, return the job results."""
    ids = [scheduler.submit(X, y, plan=p) for X, y in datasets]
    scheduler.run()
    out = []
    for jid in ids:
        job = scheduler.jobs[jid]
        if job.phase != "done":
            raise RuntimeError(f"bench job {jid} failed: {job.error!r}")
        out.append(job.result)
    return out


def meta_rows(*, n_history: int = 4, n_eval: int = 8, N: int = 400,
              d: int = 8, quick_tag: str = "quick"):
    """The ``meta`` section's ``(name, us, derived)`` rows."""
    automl = AutoMLConfig(n_trials=10, rungs=(8, 16))
    cold_plan = plan("mc", budget=200, fine_tune=False, sub_automl=automl,
                     warm_start=False)
    warm_plan = plan("mc", budget=200, fine_tune=False, sub_automl=automl)
    history = [_make_data(100 + i, N, d) for i in range(n_history)]
    evals = [_make_data(200 + i, N, d) for i in range(n_eval)]

    t0 = time.perf_counter()
    hist_sched = Scheduler(warm_min_history=n_history + 1)  # never self-warm
    _serve(hist_sched, history, warm_plan)
    hist_us = (time.perf_counter() - t0) * 1e6
    store_state = hist_sched.experience.state_dict()
    n_hist_trained = hist_sched.experience.n_trained()

    t0 = time.perf_counter()
    cold = _serve(Scheduler(), evals, cold_plan)
    cold_us = (time.perf_counter() - t0) * 1e6
    cold_trials = [r.intermediate.n_trials for r in cold]
    cold_accs = [float(r.intermediate.val_acc) for r in cold]
    cold_reach = [_trials_to_reach(r.intermediate, a)
                  for r, a in zip(cold, cold_accs)]

    t0 = time.perf_counter()
    restored = ExperienceStore()
    restored.load_state(store_state)
    warm_sched = Scheduler(experience=restored, warm_min_history=3)
    warm = _serve(warm_sched, evals, warm_plan)
    warm_us = (time.perf_counter() - t0) * 1e6
    warm_trials = [r.intermediate.n_trials for r in warm]
    warm_reach = [_trials_to_reach(r.intermediate, a)
                  for r, a in zip(warm, cold_accs)]

    hits = int(warm_sched.m_portfolio_hits.value())
    ratio = sum(warm_trials) / max(sum(cold_trials), 1)

    # the ISSUE acceptance bar, enforced by CI's bench-smoke --json run
    unreached = [i for i, r in enumerate(warm_reach) if r is None]
    assert not unreached, (
        f"warm jobs {unreached} never reached their cold winner accuracy")
    assert hits == n_eval, (
        f"only {hits}/{n_eval} warm passes were portfolio-seeded")
    assert ratio <= 0.75, (
        f"warm dispatched {sum(warm_trials)} trials vs cold "
        f"{sum(cold_trials)} (ratio {ratio:.2f} > 0.75)")

    return [
        (f"meta/history{n_history}[{quick_tag}]", hist_us,
         f"trained={n_hist_trained}"),
        (f"meta/cold{n_eval}[{quick_tag}]", cold_us,
         f"trials={sum(cold_trials)} reach={sum(cold_reach)}"),
        (f"meta/warm{n_eval}[{quick_tag}]", warm_us,
         f"trials={sum(warm_trials)} reach={sum(warm_reach)} "
         f"ratio={ratio:.2f} hits={hits}"),
    ]


if __name__ == "__main__":
    for name, us, derived in meta_rows():
        print(f"{name},{us:.1f},{derived}")
