"""Benchmark orchestrator — one section per paper table/figure + kernel
micro-benches + the service-layer / hetero-merge benches + the dry-run
roofline table.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json PATH]
                                            [--compare BASELINE.json]

Prints ``name,us_per_call,derived`` CSV blocks per section.  --full uses the
paper-scale settings (long); the default quick mode scales datasets down so
the whole suite finishes on one CPU core.  --json additionally writes every
section's rows to a machine-readable file so the perf trajectory can be
tracked across PRs (CI uploads it as ``BENCH_quick.json``) instead of
scraping CSV from stdout.  The report carries a top-level ``meta`` block
(jax/jaxlib version, device kind, CPU count, timestamp) so artifacts from
different machines are attributable.  --compare reads a previous run's
--json artifact — either layout, with or without ``meta`` —
and exits non-zero when any section regressed by more than
--compare-threshold (default 15%) in wall seconds — CI runs it against the
committed ``benchmarks/BASELINE_quick.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _section(title):
    print(f"\n### {title}", flush=True)


def _run_meta() -> dict:
    """Run context stamped into the --json report so BENCH_*.json
    trajectories are comparable across machines/toolchains."""
    import datetime
    import os

    meta = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
                     .isoformat(timespec="seconds"),
        "cpu_count": os.cpu_count(),
        "platform": sys.platform,
    }
    try:
        import jax
        import jaxlib
        meta["jax_version"] = jax.__version__
        meta["jaxlib_version"] = jaxlib.__version__
        meta["device_kind"] = jax.devices()[0].device_kind
        meta["device_count"] = jax.device_count()
    except Exception:  # noqa: BLE001 — meta is best-effort context
        meta.setdefault("jax_version", None)
    return meta


def _rowdicts(columns, rows):
    """JSON payload of one section: a list of {column: value} dicts."""
    return [dict(zip(columns, row)) for row in rows]


def _compare(report: dict, baseline_path: str, threshold: float) -> int:
    """Compare per-section wall seconds against a previous --json artifact.

    Returns the number of regressed sections (> ``threshold`` slower).
    Sections missing from either side are reported but never fail — a new
    section has no baseline, a removed one no measurement."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    base_secs = {name: sec["seconds"]
                 for name, sec in baseline.get("sections", {}).items()
                 if not sec.get("failed")}
    print(f"\n### comparison vs {baseline_path} "
          f"(threshold {threshold:.0%})")
    print("section,baseline_s,current_s,ratio,verdict")
    regressions = 0
    for name, sec in report["sections"].items():
        if sec["failed"]:
            continue
        if name not in base_secs:
            print(f"{name},-,{sec['seconds']:.3f},-,new (no baseline)")
            continue
        base = base_secs.pop(name)
        cur = sec["seconds"]
        ratio = cur / max(base, 1e-9)
        regressed = ratio > 1.0 + threshold
        regressions += int(regressed)
        print(f"{name},{base:.3f},{cur:.3f},{ratio:.2f}x,"
              f"{'REGRESSED' if regressed else 'ok'}")
    for name in base_secs:
        print(f"{name},{base_secs[name]:.3f},-,-,missing from this run")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[],
                    help="section names to skip (table4 fig2 fig3 fig4 fig5 "
                         "kernels gen_dst automl service service_transport "
                         "hetero_merge continuous_batching meta roofline)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write each section's rows to a machine-readable "
                         "JSON file (perf trajectory tracking across PRs)")
    ap.add_argument("--compare", metavar="BASELINE", default=None,
                    help="previous --json artifact to compare against; "
                         "exits 2 when any section regresses by more than "
                         "--compare-threshold in wall seconds")
    ap.add_argument("--compare-threshold", type=float, default=0.15,
                    help="allowed per-section slowdown fraction "
                         "(default 0.15 = 15%%)")
    ap.add_argument("--compare-only", metavar="CURRENT", default=None,
                    help="skip running benchmarks; compare an existing "
                         "--json artifact against --compare's baseline")
    args = ap.parse_args()

    if args.compare_only:
        if not args.compare:
            ap.error("--compare-only requires --compare BASELINE.json")
        with open(args.compare_only) as f:
            report = json.load(f)
        regressions = _compare(report, args.compare, args.compare_threshold)
        print(f"# {regressions} section regressions "
              f"(>{args.compare_threshold:.0%} slower)")
        sys.exit(2 if regressions else 0)

    quick = not args.full
    t_start = time.time()

    sections = []

    if "kernels" not in args.skip:
        sections.append(("kernels", _run_kernels))
    if "gen_dst" not in args.skip:
        sections.append(("gen_dst", lambda: _run_gen_dst(quick)))
    if "automl" not in args.skip:
        sections.append(("automl", lambda: _run_automl(quick)))
    if "service" not in args.skip:
        sections.append(("service", lambda: _run_service(quick)))
    if "service_transport" not in args.skip:
        sections.append(("service_transport", lambda: _run_transport(quick)))
    if "hetero_merge" not in args.skip:
        sections.append(("hetero_merge", lambda: _run_hetero(quick)))
    if "continuous_batching" not in args.skip:
        sections.append(("continuous_batching", lambda: _run_continuous(quick)))
    if "meta" not in args.skip:
        sections.append(("meta", lambda: _run_meta_learning(quick)))
    if "table4" not in args.skip:
        sections.append(("table4", lambda: _run_table4(quick)))
    if "fig2" not in args.skip:
        sections.append(("fig2", lambda: _run_fig2(quick)))
    if "fig3" not in args.skip:
        sections.append(("fig3", lambda: _run_fig3(quick)))
    if "fig4" not in args.skip:
        sections.append(("fig4", lambda: _run_fig4(quick)))
    if "fig5" not in args.skip:
        sections.append(("fig5", lambda: _run_fig5(quick)))
    if "roofline" not in args.skip:
        sections.append(("roofline", _run_roofline))

    report = {"quick": quick, "meta": _run_meta(), "sections": {}}
    failures = 0
    for name, fn in sections:
        t0 = time.time()
        try:
            rows = fn()
        except Exception:  # noqa: BLE001 — keep the suite running
            failures += 1
            rows = None
            print(f"SECTION {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
        dt = time.time() - t0
        report["sections"][name] = {
            "seconds": round(dt, 3),
            "failed": rows is None,
            "rows": rows if rows is not None else [],
        }
        print(f"# section {name} took {dt:.1f}s", flush=True)
    report["failures"] = failures
    report["total_s"] = round(time.time() - t_start, 3)
    print(f"\n# benchmarks done in {report['total_s']:.1f}s, "
          f"{failures} section failures")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, default=float)
        print(f"# wrote {args.json}")
    regressions = 0
    if args.compare:
        regressions = _compare(report, args.compare, args.compare_threshold)
        print(f"# {regressions} section regressions "
              f"(>{args.compare_threshold:.0%} slower)")
    if failures:
        sys.exit(1)
    if regressions:
        sys.exit(2)


def _run_kernels():
    _section("kernel micro-benchmarks (name,us_per_call,derived)")
    from .kernels_bench import main as kmain
    rows = [(name, round(us, 1), derived) for name, us, derived in kmain()]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us_per_call", "derived"), rows)


def _run_gen_dst(quick):
    _section("Gen-DST search loop: incremental fitness + islands "
             "(name,us_per_generation,derived)")
    from .kernels_bench import gen_dst_fused_rows, gen_dst_rows
    if quick:
        rows = gen_dst_rows(N=20_000, psi=12, quick_tag="20k")
        rows += gen_dst_fused_rows(N=20_000, psi=6, phi=16, quick_tag="20k")
    else:
        rows = gen_dst_rows(N=100_000, psi=24, quick_tag="100k")
        rows += gen_dst_fused_rows(N=100_000, psi=12, phi=64,
                                   quick_tag="100k")
    rows = [(name, round(us, 1), derived) for name, us, derived in rows]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us_per_generation", "derived"), rows)


def _run_automl(quick):
    _section("AutoML engine: sequential loop vs batched cohort, default "
             "24-trial/3-rung budget (name,us,derived)")
    from .automl_bench import automl_rows
    # dst100 = the sub-AutoML regime (DST of quickstart's 10k-row dataset);
    # the larger dataset shows the compute-bound end of the scale
    rows = automl_rows(N=100, d=12, quick_tag="dst100")
    rows += automl_rows(N=2_000 if quick else 10_000, d=12,
                        quick_tag="2k" if quick else "10k", reps=2)
    rows = [(name, round(us, 1), derived) for name, us, derived in rows]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us", "derived"), rows)


def _run_service(quick):
    _section("Service layer: 8 concurrent jobs (DST cache + warm start + "
             "cross-job rung merge) vs sequential substrat (name,us,derived)")
    from .service_bench import service_rows
    if quick:
        rows = service_rows(n_jobs=8, N=2_000, d=10, quick_tag="2k")
    else:
        rows = service_rows(n_jobs=8, N=10_000, d=14, quick_tag="10k")
    rows = [(name, round(us, 1), derived) for name, us, derived in rows]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us", "derived"), rows)


def _run_transport(quick):
    _section("Cross-process serving tier: in-process vs 1 vs 2 worker "
             "subprocesses + crash recovery overhead (name,us,derived)")
    from .transport_bench import transport_rows
    rows = transport_rows(n_jobs=4, N=512 if quick else 2_000,
                          quick_tag="quick" if quick else "full")
    rows = [(name, round(us, 1), derived) for name, us, derived in rows]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us", "derived"), rows)


def _run_hetero(quick):
    _section("Heterogeneous merge: shape-padded cross-job rung dispatch + "
             "batched Gen-DST (name,us,derived)")
    from .hetero_bench import hetero_rows
    rows = hetero_rows(n_jobs=4, quick_tag="quick" if quick else "full")
    rows = [(name, round(us, 1), derived) for name, us, derived in rows]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us", "derived"), rows)


def _run_continuous(quick):
    _section("Continuous rung batching: lockstep (rung_i, epochs) buckets vs "
             "cross-rung step-masked megabatch (name,us,derived)")
    from .continuous_bench import continuous_rows
    rows = continuous_rows(n_jobs=8, quick_tag="quick" if quick else "full")
    rows = [(name, round(us, 1), derived) for name, us, derived in rows]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us", "derived"), rows)


def _run_meta_learning(quick):
    _section("Cross-tenant meta-learning: trials to reach the cold winner "
             "accuracy, cold vs portfolio-warm-started (name,us,derived)")
    from .meta_bench import meta_rows
    if quick:
        rows = meta_rows(n_history=4, n_eval=8, N=400, d=8,
                         quick_tag="quick")
    else:
        rows = meta_rows(n_history=8, n_eval=8, N=2_000, d=10,
                         quick_tag="full")
    rows = [(name, round(us, 1), derived) for name, us, derived in rows]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return _rowdicts(("name", "us", "derived"), rows)


def _run_table4(quick):
    _section("Table 4: mean time-reduction / relative-accuracy per method")
    from .table4_baselines import main as t4
    datasets = ("D2", "D3", "D6") if quick else tuple(f"D{i}" for i in range(1, 11))
    table = t4(datasets=datasets, scale=0.2 if quick else 1.0,
               reps=1 if quick else 5, print_rows=False)
    print("method,time_reduction_mean,time_reduction_std,rel_acc_mean,rel_acc_std")
    rows = []
    for m, (trm, trs, ram, ras) in sorted(table.items(), key=lambda kv: -kv[1][2]):
        print(f"{m},{trm:.4f},{trs:.4f},{ram:.4f},{ras:.4f}")
        rows.append((m, trm, trs, ram, ras))
    return _rowdicts(("method", "time_reduction_mean", "time_reduction_std",
                      "rel_acc_mean", "rel_acc_std"), rows)


def _run_fig2(quick):
    _section("Figure 2: per-dataset points")
    from .fig2_per_dataset import main as f2
    print("dataset,method,time_reduction,relative_accuracy")
    rows = list(f2(scale=0.2 if quick else 1.0))
    for ds, m, tr, ra in rows:
        print(f"{ds},{m},{tr:.4f},{ra:.4f}")
    return _rowdicts(("dataset", "method", "time_reduction",
                      "relative_accuracy"), rows)


def _run_fig3(quick):
    _section("Figure 3: SubStrat settings skyline")
    from .fig3_skyline import main as f3
    points, skyline = f3(scale=0.2 if quick else 1.0)
    sky = {p[0] for p in skyline}
    print("setting,time_reduction,relative_accuracy,on_skyline")
    rows = []
    for name, tr, ra in points:
        print(f"{name},{tr:.4f},{ra:.4f},{name in sky}")
        rows.append((name, tr, ra, name in sky))
    return _rowdicts(("setting", "time_reduction", "relative_accuracy",
                      "on_skyline"), rows)


def _run_fig4(quick):
    _section("Figure 4: DST size heatmap")
    from .fig4_dst_size import main as f4
    print("n,m,time_reduction,relative_accuracy")
    rows = list(f4(scale=0.15 if quick else 1.0))
    for n, m, tr, ra in rows:
        print(f"{n},{m},{tr:.4f},{ra:.4f}")
    return _rowdicts(("n", "m", "time_reduction", "relative_accuracy"), rows)


def _run_fig5(quick):
    _section("Figure 5: isolated n / m sweeps")
    from .fig5_isolated import main as f5
    lp, wp = f5(scale=0.15 if quick else 1.0)
    print("axis,value,time_reduction,relative_accuracy")
    rows = []
    for n, tr, ra in lp:
        print(f"n,{n},{tr:.4f},{ra:.4f}")
        rows.append(("n", n, tr, ra))
    for m, tr, ra in wp:
        print(f"m,{m},{tr:.4f},{ra:.4f}")
        rows.append(("m", m, tr, ra))
    return _rowdicts(("axis", "value", "time_reduction",
                      "relative_accuracy"), rows)


def _run_roofline():
    _section("Roofline (experiments/dryrun.json + analytic Gen-DST fused "
             "generation)")
    from .roofline import gen_dst_rows, main as rmain, rows as roofline_rows
    rmain()
    return _rowdicts(
        ("arch", "shape", "status", "dominant", "compute_s", "memory_s",
         "collective_s", "roofline_fraction", "useful_flops_ratio",
         "peak_gb_per_dev"),
        roofline_rows() + gen_dst_rows())


if __name__ == "__main__":
    main()
