"""Benchmark orchestrator — one section per paper table/figure + kernel
micro-benches + the dry-run roofline table.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV blocks per section.  --full uses the
paper-scale settings (long); the default quick mode scales datasets down so
the whole suite finishes on one CPU core.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(title):
    print(f"\n### {title}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[],
                    help="section names to skip (table4 fig2 fig3 fig4 fig5 "
                         "kernels gen_dst automl roofline)")
    args = ap.parse_args()

    quick = not args.full
    t_start = time.time()

    sections = []

    if "kernels" not in args.skip:
        sections.append(("kernels", _run_kernels))
    if "gen_dst" not in args.skip:
        sections.append(("gen_dst", lambda: _run_gen_dst(quick)))
    if "automl" not in args.skip:
        sections.append(("automl", lambda: _run_automl(quick)))
    if "table4" not in args.skip:
        sections.append(("table4", lambda: _run_table4(quick)))
    if "fig2" not in args.skip:
        sections.append(("fig2", lambda: _run_fig2(quick)))
    if "fig3" not in args.skip:
        sections.append(("fig3", lambda: _run_fig3(quick)))
    if "fig4" not in args.skip:
        sections.append(("fig4", lambda: _run_fig4(quick)))
    if "fig5" not in args.skip:
        sections.append(("fig5", lambda: _run_fig5(quick)))
    if "roofline" not in args.skip:
        sections.append(("roofline", _run_roofline))

    failures = 0
    for name, fn in sections:
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001 — keep the suite running
            failures += 1
            print(f"SECTION {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
        print(f"# section {name} took {time.time()-t0:.1f}s", flush=True)
    print(f"\n# benchmarks done in {time.time()-t_start:.1f}s, "
          f"{failures} section failures")
    if failures:
        sys.exit(1)


def _run_kernels():
    _section("kernel micro-benchmarks (name,us_per_call,derived)")
    from .kernels_bench import main as kmain
    for name, us, derived in kmain():
        print(f"{name},{us:.1f},{derived}")


def _run_gen_dst(quick):
    _section("Gen-DST search loop: incremental fitness + islands "
             "(name,us_per_generation,derived)")
    from .kernels_bench import gen_dst_rows
    if quick:
        rows = gen_dst_rows(N=20_000, psi=12, quick_tag="20k")
    else:
        rows = gen_dst_rows(N=100_000, psi=24, quick_tag="100k")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def _run_automl(quick):
    _section("AutoML engine: sequential loop vs batched cohort, default "
             "24-trial/3-rung budget (name,us,derived)")
    from .automl_bench import automl_rows
    # dst100 = the sub-AutoML regime (DST of quickstart's 10k-row dataset);
    # the larger dataset shows the compute-bound end of the scale
    rows = automl_rows(N=100, d=12, quick_tag="dst100")
    rows += automl_rows(N=2_000 if quick else 10_000, d=12,
                        quick_tag="2k" if quick else "10k", reps=2)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def _run_table4(quick):
    _section("Table 4: mean time-reduction / relative-accuracy per method")
    from .table4_baselines import main as t4
    datasets = ("D2", "D3", "D6") if quick else tuple(f"D{i}" for i in range(1, 11))
    table = t4(datasets=datasets, scale=0.2 if quick else 1.0,
               reps=1 if quick else 5, print_rows=False)
    print("method,time_reduction_mean,time_reduction_std,rel_acc_mean,rel_acc_std")
    for m, (trm, trs, ram, ras) in sorted(table.items(), key=lambda kv: -kv[1][2]):
        print(f"{m},{trm:.4f},{trs:.4f},{ram:.4f},{ras:.4f}")


def _run_fig2(quick):
    _section("Figure 2: per-dataset points")
    from .fig2_per_dataset import main as f2
    print("dataset,method,time_reduction,relative_accuracy")
    for ds, m, tr, ra in f2(scale=0.2 if quick else 1.0):
        print(f"{ds},{m},{tr:.4f},{ra:.4f}")


def _run_fig3(quick):
    _section("Figure 3: SubStrat settings skyline")
    from .fig3_skyline import main as f3
    points, skyline = f3(scale=0.2 if quick else 1.0)
    sky = {p[0] for p in skyline}
    print("setting,time_reduction,relative_accuracy,on_skyline")
    for name, tr, ra in points:
        print(f"{name},{tr:.4f},{ra:.4f},{name in sky}")


def _run_fig4(quick):
    _section("Figure 4: DST size heatmap")
    from .fig4_dst_size import main as f4
    print("n,m,time_reduction,relative_accuracy")
    for n, m, tr, ra in f4(scale=0.15 if quick else 1.0):
        print(f"{n},{m},{tr:.4f},{ra:.4f}")


def _run_fig5(quick):
    _section("Figure 5: isolated n / m sweeps")
    from .fig5_isolated import main as f5
    lp, wp = f5(scale=0.15 if quick else 1.0)
    print("axis,value,time_reduction,relative_accuracy")
    for n, tr, ra in lp:
        print(f"n,{n},{tr:.4f},{ra:.4f}")
    for m, tr, ra in wp:
        print(f"m,{m},{tr:.4f},{ra:.4f}")


def _run_roofline():
    _section("Roofline (from experiments/dryrun.json)")
    from .roofline import main as rmain
    rmain()


if __name__ == "__main__":
    main()
