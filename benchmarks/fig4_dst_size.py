"""Figure 4: effect of DST size (n rows x m cols) on accuracy/time — the
(sqrt(N), 0.25M) sweet spot."""
from __future__ import annotations

import numpy as np

from repro.data.tabular import PAPER_DATASETS, make_dataset, train_test_split
from .common import run_dataset, substrat_config


def main(dataset="D3", scale=0.2):
    spec = PAPER_DATASETS[dataset]
    X, _ = make_dataset(spec, scale=scale)
    N, M = X.shape
    n_grid = [max(4, int(np.log2(N))), int(N ** 0.5), int(N ** 0.75)]
    m_grid = [max(2, int(0.1 * M)), max(2, int(0.25 * M)), max(2, int(0.5 * M))]
    cells = []
    for n in n_grid:
        for m in m_grid:
            cfg = substrat_config(n=n, m=m)
            _, results = run_dataset(spec, scale=scale, methods=["SubStrat"],
                                     sub_cfg=cfg)
            r = results[0]
            cells.append((n, m, r.time_reduction, r.relative_accuracy))
    return cells


if __name__ == "__main__":
    print("n,m,time_reduction,relative_accuracy")
    for n, m, tr, ra in main():
        print(f"{n},{m},{tr:.4f},{ra:.4f}")
