"""AutoML engine benchmark: sequential loop vs batched cohort (DESIGN.md §10.3).

Runs ``automl_fit`` at the default 24-trial / 3-rung successive-halving
budget with both backends on the same synthetic dataset and reports
steady-state (post-compile) per-rung and total times, the end-to-end
speedup, and same-seed winner parity.  Compile costs are amortized by one
untimed warmup run per backend, mirroring the ``gen_dst_100k_steady``
convention in ``kernels_bench.py``.

Acceptance target (ISSUE 2): batched >= 3x over loop at the default budget.
"""
from __future__ import annotations

import time

import numpy as np

from repro.automl.engine import AutoMLConfig, automl_fit


def _make_data(N: int, d: int, n_classes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, N)
    X = np.column_stack([
        (y == k % n_classes) * 1.5 + rng.normal(0, 0.8, N) for k in range(d)
    ]).astype(np.float32)
    return X, y


def automl_rows(N=100, d=12, n_classes=3, quick_tag="dst100", reps=7):
    """Returns ``(name, us, derived)`` rows for the ``automl`` bench section.

    The default ``N=100`` is the sub-AutoML regime SubStrat cares about —
    the DST of the repo's canonical 10k-row dataset (quickstart's D3) has
    ``sqrt(N) = 100`` rows — where the loop backend's per-trial
    dispatch/sync overhead dominates.  Timings are best-of-``reps``
    steady-state runs after one untimed warmup."""
    X, y = _make_data(N, d, n_classes)
    rows, results = [], {}
    for backend in ("loop", "batched"):
        cfg = AutoMLConfig(backend=backend)        # default 24-trial / 3-rung
        automl_fit(X, y, config=cfg)               # warmup: pay jit compiles
        best, res = None, None
        for _ in range(reps):
            t0 = time.perf_counter()
            r = automl_fit(X, y, config=cfg)
            t = time.perf_counter() - t0
            if best is None or t < best:
                best, res = t, r
        results[backend] = (best, res)
        for r_i, t_rung in enumerate(res.rung_times):
            rows.append((f"automl_rung{r_i}_{backend}_{quick_tag}", t_rung * 1e6,
                         f"epochs={cfg.rungs[r_i]}"))
        rows.append((f"automl_total_{backend}_{quick_tag}", best * 1e6,
                     f"n_trials={res.n_trials}"))
    t_loop, r_loop = results["loop"]
    t_bat, r_bat = results["batched"]
    rows.append((
        f"automl_batched_speedup_{quick_tag}", t_bat * 1e6,
        f"speedup={t_loop / t_bat:.2f}x winner_parity={r_loop.spec == r_bat.spec}",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in automl_rows():
        print(f"{name},{us:.1f},{derived}")
