"""Figure 2: per-dataset (time-reduction, relative-accuracy) scatter points."""
from __future__ import annotations

from repro.data.tabular import PAPER_DATASETS
from .common import run_dataset


def main(datasets=("D2", "D3", "D6"), scale=0.2,
         methods=("SubStrat", "IG-KM", "MC-100")):
    points = []
    for ds in datasets:
        _, results = run_dataset(PAPER_DATASETS[ds], scale=scale,
                                 methods=list(methods))
        for r in results:
            points.append((ds, r.method, r.time_reduction, r.relative_accuracy))
    return points


if __name__ == "__main__":
    print("dataset,method,time_reduction,relative_accuracy")
    for ds, m, tr, ra in main():
        print(f"{ds},{m},{tr:.4f},{ra:.4f}")
