"""Continuous rung batching benchmark (DESIGN.md §13): throughput of the
standing cross-rung megabatch vs lockstep ``(rung_i, epochs)`` bucketing.

The workload is the ragged-traffic serving regime the megabatch targets:
8 concurrent sub-AutoML searches on DST-sized data (~100 rows) whose rung
ladders deliberately do *not* line up — eight distinct ``(rungs)`` tuples,
so at every scheduler step the lockstep dispatcher fragments the fleet into
singleton ``(rung_i, epochs)`` buckets (one program launch per live job)
while the megabatch packs every ready cohort into one step-masked dispatch
(``eval_trial_megabatch``) under the waste budget.  At this scale each
dispatch costs far more in host round-trips and program launch than the
padded scan slots cost in FLOPs, which is exactly the asymmetry continuous
batching exploits (same argument as the §12.4 hetero merge, extended to the
time axis).  Same-shaped jobs keep every merge bit-identical, so the
speedup is pure scheduling — no accuracy trade.

Acceptance target (ISSUE 6): >= 1.3x throughput at 8 jobs, mixed ladders.
"""
from __future__ import annotations

import time

import numpy as np

from repro.automl.engine import (
    AutoMLConfig, search_init, search_record, search_trial_cohort,
)
from repro.automl.batched import eval_rung_cohorts, eval_trial_megabatch
from repro.service.scheduler import CohortMeta, pack_megabatches

# ragged rung mix: eight tenants, eight distinct ladders — no two jobs ever
# share a lockstep (rung_i, epochs) bucket, so the pre-§13 dispatcher runs
# one program launch per live job per rung while the megabatch runs one
# total.  Budgets are distinct but close (8..15 then 16..30) so the step
# padding the megabatch pays stays small next to the launches it saves.
_LADDERS = ((8, 16), (9, 18), (10, 20), (11, 22),
            (12, 24), (13, 26), (14, 28), (15, 30))


def _make_data(seed: int, N: int, d: int, C: int):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, C, N)
    X = np.column_stack(
        [y * 1.2 + rng.normal(0, 0.8, N) for _ in range(d)]).astype(np.float32)
    return X, y


def _measure(fn, reps: int = 9) -> float:
    fn()                                  # warmup: pay jit compiles
    return min(fn() for _ in range(reps))


def continuous_rows(n_jobs: int = 8, waste_budget: float = 4.0,
                    quick_tag: str = "quick"):
    """Returns ``(name, us, derived)`` rows for ``continuous_batching``."""
    ladders = _LADDERS[:n_jobs]
    data = [_make_data(11 + i, 100, 8, 2) for i in range(n_jobs)]
    cfgs = [AutoMLConfig(n_trials=4, rungs=ladders[i], seed=i)
            for i in range(n_jobs)]
    dispatches = {"lockstep": 0, "megabatch": 0}

    def run_lockstep():
        """The pre-§13 scheduler: merge only within (rung_i, epochs)."""
        states = [search_init(X, y, config=cfg)
                  for (X, y), cfg in zip(data, cfgs)]
        t0 = time.perf_counter()      # time the dispatch loop, not job setup
        n_disp = 0
        while not all(s.done for s in states):
            buckets = {}
            for s in states:
                if s.done:
                    continue
                buckets.setdefault(
                    (s.rung_i, int(s.config.rungs[s.rung_i])), []).append(s)
            for bucket in buckets.values():
                outs = eval_rung_cohorts(
                    [search_trial_cohort(s) for s in bucket])
                n_disp += 1
                for s, (scored, positions) in zip(bucket, outs):
                    search_record(s, scored, positions, 0.0)
        dispatches["lockstep"] = n_disp
        return time.perf_counter() - t0

    def run_megabatch():
        """§13: every ready cohort joins one standing step-masked dispatch."""
        states = [search_init(X, y, config=cfg)
                  for (X, y), cfg in zip(data, cfgs)]
        t0 = time.perf_counter()      # time the dispatch loop, not job setup
        n_disp = 0
        while not all(s.done for s in states):
            live = [s for s in states if not s.done]
            cohorts = [search_trial_cohort(s) for s in live]
            metas = [CohortMeta(tc.shape, tc.trial_steps) for tc in cohorts]
            for g in pack_megabatches(metas, waste_budget):
                outs = eval_trial_megabatch([cohorts[i] for i in g])
                n_disp += 1
                for i, (scored, positions) in zip(g, outs):
                    search_record(live[i], scored, positions, 0.0)
        dispatches["megabatch"] = n_disp
        return time.perf_counter() - t0

    t_lock = _measure(run_lockstep)
    t_mega = _measure(run_megabatch)
    ladder_mix = "/".join("-".join(map(str, l)) for l in sorted(set(ladders)))
    return [
        (f"lockstep_{n_jobs}jobs_{quick_tag}", t_lock * 1e6,
         f"dispatches={dispatches['lockstep']} ladders={ladder_mix}"),
        (f"megabatch_{n_jobs}jobs_{quick_tag}", t_mega * 1e6,
         f"speedup={t_lock / t_mega:.2f}x "
         f"dispatches={dispatches['megabatch']} "
         f"waste_budget={waste_budget} (target >=1.3x)"),
    ]


if __name__ == "__main__":
    for name, us, derived in continuous_rows():
        print(f"{name},{us:.1f},{derived}")
