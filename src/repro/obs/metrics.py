"""Metrics registry (DESIGN.md §15.3): counters, gauges, histograms.

Stdlib-only, with two export surfaces and one persistence surface:

- ``render()``   — Prometheus text exposition (version 0.0.4), served by
  ``GET /v1/metrics``;
- ``to_dict()``  — the in-process view (nested plain dicts) surfaced in
  ``stats()`` payloads;
- ``state_dict()`` / ``load_state()`` — a bit-identical round trip: the
  scheduler checkpoints its registry alongside jobs and spans, so a
  resumed front end reports continuous counters instead of rebooted ones.

Families are get-or-create: re-registering an existing name with the same
type returns the live family, which makes ``load_state`` + later
constructor registration idempotent (restore first, re-register after).

Label values are stored per-child keyed by the tuple of values in
declared label order; children materialize on first touch, so an
unexercised labelled family renders only its HELP/TYPE header.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "render_exposition_line"]

DEFAULT_BUCKETS = (0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0, 300.0)


def _escape_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label(value: str) -> str:
    return (value.replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def render_exposition_line(name: str, labels: Sequence[Tuple[str, str]],
                           value: float) -> str:
    """One Prometheus sample line, labels rendered in declared order."""
    label_s = ""
    if labels:
        inner = ",".join(f'{k}="{_escape_label(str(v))}"' for k, v in labels)
        label_s = "{" + inner + "}"
    if isinstance(value, float) and value == int(value) and abs(value) < 1e15:
        return f"{name}{label_s} {int(value)}"
    return f"{name}{label_s} {value}"


class _Family:
    """Shared machinery: one metric name + label schema, many children."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._values: Dict[Tuple[str, ...], float] = {}
        self._lock = threading.Lock()

    def _key(self, labels: dict) -> Tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[k]) for k in self.label_names)

    # -- exports -------------------------------------------------------------

    def to_dict(self) -> dict:
        with self._lock:
            if not self.label_names:
                return {"value": self._values.get((), 0.0)}
            return {"values": {",".join(k): v
                               for k, v in sorted(self._values.items())}}

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {_escape_help(self.help)}",
                 f"# TYPE {self.name} {self.kind}"]
        with self._lock:
            if not self.label_names and () not in self._values:
                self._values[()] = 0.0   # label-less metrics always sample
            for key in sorted(self._values):
                lines.append(render_exposition_line(
                    self.name, list(zip(self.label_names, key)),
                    self._values[key]))
        return lines

    # -- persistence ---------------------------------------------------------

    def state(self) -> dict:
        with self._lock:
            return {"kind": self.kind, "help": self.help,
                    "labels": list(self.label_names),
                    "values": sorted((list(k), v)
                                     for k, v in self._values.items())}

    def load(self, state: dict) -> None:
        with self._lock:
            self._values = {tuple(k): v for k, v in state["values"]}


class Counter(_Family):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)


class Gauge(_Family):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[self._key(labels)] = float(value)

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)


class Histogram(_Family):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str, labels: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        # per-child: [per-bucket counts..., +Inf count, sum]
        self._hv: Dict[Tuple[str, ...], List[float]] = {}

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            row = self._hv.setdefault(key, [0.0] * (len(self.buckets) + 2))
            for i, b in enumerate(self.buckets):
                if value <= b:
                    row[i] += 1.0
            row[-2] += 1.0          # +Inf / count
            row[-1] += float(value)  # sum

    def count(self, **labels) -> float:
        with self._lock:
            row = self._hv.get(self._key(labels))
            return row[-2] if row else 0.0

    def to_dict(self) -> dict:
        with self._lock:
            return {"buckets": list(self.buckets),
                    "series": {",".join(k): {"counts": row[:-1],
                                             "sum": row[-1]}
                               for k, row in sorted(self._hv.items())}}

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {_escape_help(self.help)}",
                 f"# TYPE {self.name} histogram"]
        with self._lock:
            items = sorted(self._hv.items()) or ([
                ((), [0.0] * (len(self.buckets) + 2))]
                if not self.label_names else [])
            for key, row in items:
                base = list(zip(self.label_names, key))
                cum = 0.0
                for i, b in enumerate(self.buckets):
                    cum = row[i]
                    lines.append(render_exposition_line(
                        f"{self.name}_bucket", base + [("le", repr(b))], cum))
                lines.append(render_exposition_line(
                    f"{self.name}_bucket", base + [("le", "+Inf")], row[-2]))
                lines.append(render_exposition_line(
                    f"{self.name}_sum", base, row[-1]))
                lines.append(render_exposition_line(
                    f"{self.name}_count", base, row[-2]))
        return lines

    def state(self) -> dict:
        with self._lock:
            return {"kind": self.kind, "help": self.help,
                    "labels": list(self.label_names),
                    "buckets": list(self.buckets),
                    "values": sorted((list(k), list(row))
                                     for k, row in self._hv.items())}

    def load(self, state: dict) -> None:
        with self._lock:
            self.buckets = tuple(state["buckets"])
            self._hv = {tuple(k): list(row) for k, row in state["values"]}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """A named collection of metric families with deterministic export."""

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _register(self, cls, name: str, help: str, labels=(), **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls) or fam.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind} with labels {fam.label_names}")
                return fam
            fam = cls(name, help, labels, **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str) -> Optional[_Family]:
        with self._lock:
            return self._families.get(name)

    # -- exports -------------------------------------------------------------

    def render(self) -> str:
        """The full Prometheus text exposition (families in name order)."""
        with self._lock:
            fams = [self._families[n] for n in sorted(self._families)]
        return "\n".join(line for f in fams for line in f.render()) + "\n"

    def to_dict(self) -> dict:
        with self._lock:
            fams = sorted(self._families.items())
        return {name: {"kind": f.kind, **f.to_dict()} for name, f in fams}

    # -- persistence (bit-identical round trip) ------------------------------

    def state_dict(self) -> dict:
        with self._lock:
            fams = sorted(self._families.items())
        return {name: f.state() for name, f in fams}

    def load_state(self, state: dict) -> None:
        """Replace the registry contents with ``state`` — families are
        recreated wholesale from their persisted schema, so
        ``state_dict()`` after a load is bit-identical to the source."""
        with self._lock:
            self._families.clear()
        for name, fs in state.items():
            cls = _KINDS[fs["kind"]]
            kw = {"buckets": fs["buckets"]} if fs["kind"] == "histogram" else {}
            fam = self._register(cls, name, fs["help"], fs["labels"], **kw)
            fam.load(fs)
