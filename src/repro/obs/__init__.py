"""Observability subsystem (DESIGN.md §15): tracing, metrics, JAX
compile/dispatch accounting.  Zero dependencies beyond the stdlib — the
serving tier imports this unconditionally.

- ``obs.trace``   — structured spans with deterministic ids, a contextvar
  current-span, and cross-process propagation through the wire header.
- ``obs.metrics`` — counters/gauges/histograms with Prometheus text
  exposition and a bit-identical state round-trip for checkpoints.
- ``obs.jaxprof`` — jit-retracing counters per call-site, padded-vs-useful
  FLOP accounting for megabatch packs, and an opt-in per-dispatch profile
  hook.
"""
from . import jaxprof, metrics, trace

__all__ = ["jaxprof", "metrics", "trace"]
