"""JAX compile/dispatch accounting (DESIGN.md §15.4).

Three small instruments, all process-global (compilation caches are):

**Retracing counters.**  Every jitted hot-path kernel calls
``note_trace("<site>")`` as its first statement.  A jitted function body
only executes while JAX is *tracing* it — a cache hit dispatches the
compiled executable without touching Python — so the counter counts
exactly one increment per (re)trace per call-site.  This is the
measurement behind two serving claims: the §13 traced-scalar step masks
mean mixed rung budgets share one compilation, and steady-state serving
after warmup performs **zero** new tracings (the CI recompile-budget gate
asserts both).  ``tracing_snapshot()``/``new_tracings_since()`` implement
the gate's warmup/steady-state delta.

**FLOP accounting.**  ``pack_flops(metas)`` prices one megabatch pack:
every trial costs the group-maximal padded shape at the group-maximal
scan length, its useful work is its own shape at its own step budget —
the absolute-FLOPs companion of the scheduler's relative ``merge_waste``
ratio, built on ``launch/flops.py``'s analytic ``tabular_trial_flops``.

**Dispatch profile hook.**  Opt-in: ``set_dispatch_hook(fn)`` installs a
callable that receives ``(name, seconds, meta)`` after every scheduler
dispatch — the seam for wiring ``jax.profiler`` traces or external
telemetry to exactly the dispatches of interest without patching the
scheduler.  ``install_monitoring()`` additionally subscribes to
``jax.monitoring`` events (best-effort; event names vary by jax version)
so XLA's own compile events land in the same exposition.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional, Sequence

from .metrics import render_exposition_line

__all__ = ["dispatch_event", "install_monitoring", "new_tracings_since",
           "note_trace", "pack_flops", "render_prometheus", "reset_tracing",
           "set_dispatch_hook", "total_tracings", "tracing_counts",
           "tracing_snapshot"]

_lock = threading.Lock()
_TRACE_COUNTS: Dict[str, int] = {}
_XLA_EVENTS: Dict[str, int] = {}
_monitoring_installed = False
_dispatch_hook: Optional[Callable] = None


# ---------------------------------------------------------------------------
# retracing counters
# ---------------------------------------------------------------------------


def note_trace(site: str) -> None:
    """Count one jit tracing of ``site``.

    Call as the first statement of a jitted function body: the body runs
    once per trace (compilation-cache miss) and never on a cached
    dispatch, so the count is exactly the number of compilations XLA was
    asked for at this call-site."""
    with _lock:
        _TRACE_COUNTS[site] = _TRACE_COUNTS.get(site, 0) + 1


def tracing_counts() -> Dict[str, int]:
    """Per-site tracing counts since process start (or ``reset_tracing``)."""
    with _lock:
        return dict(_TRACE_COUNTS)


def total_tracings() -> int:
    with _lock:
        return sum(_TRACE_COUNTS.values())


def tracing_snapshot() -> Dict[str, int]:
    """Alias of ``tracing_counts`` named for the warmup/steady-state
    protocol: snapshot after warmup, diff after steady-state traffic."""
    return tracing_counts()


def new_tracings_since(snapshot: Dict[str, int]) -> Dict[str, int]:
    """Per-site tracings that happened after ``snapshot`` was taken
    (empty dict == the recompile budget held)."""
    now = tracing_counts()
    delta = {site: n - snapshot.get(site, 0) for site, n in now.items()}
    return {site: n for site, n in delta.items() if n > 0}


def reset_tracing() -> None:
    with _lock:
        _TRACE_COUNTS.clear()


# ---------------------------------------------------------------------------
# jax.monitoring bridge (best-effort)
# ---------------------------------------------------------------------------


def _on_event(event: str, **_kw) -> None:
    with _lock:
        _XLA_EVENTS[event] = _XLA_EVENTS.get(event, 0) + 1


def install_monitoring() -> bool:
    """Subscribe to ``jax.monitoring`` events once per process.

    Returns True when the listener is (already) installed.  Event names
    are jax-internal and version-dependent; the counters are exported
    verbatim under ``jax_monitoring_events_total{event=...}`` as
    corroborating evidence next to the first-class ``note_trace``
    counters, never as the primary signal."""
    global _monitoring_installed
    if _monitoring_installed:
        return True
    try:
        from jax import monitoring
        monitoring.register_event_listener(_on_event)
        _monitoring_installed = True
    except Exception:   # noqa: BLE001 — older jax / no monitoring: degrade
        return False
    return True


# ---------------------------------------------------------------------------
# megabatch FLOP accounting
# ---------------------------------------------------------------------------


def pack_flops(metas: Sequence) -> tuple:
    """``(padded_flops, useful_flops)`` of one megabatch pack.

    ``metas`` are the scheduler's ``CohortMeta`` entries: ``shape =
    (N_tr, N_val, d, n_classes)`` plus per-trial ``steps``.  Padded cost
    prices every trial at the group-maximal shape and scan length (what
    the fused dispatch actually executes); useful cost is each trial's
    own shape and budget (what a solo run would have needed)."""
    from ..launch.flops import tabular_trial_flops
    ntr = max(m.shape[0] for m in metas)
    nval = max(m.shape[1] for m in metas)
    d = max(m.shape[2] for m in metas)
    c = max(m.shape[3] for m in metas)
    smax = max(max(m.steps) for m in metas)
    n_trials = sum(len(m.steps) for m in metas)
    padded = n_trials * tabular_trial_flops(ntr, nval, d, c, smax)
    useful = sum(
        tabular_trial_flops(m.shape[0], m.shape[1], m.shape[2], m.shape[3], st)
        for m in metas for st in m.steps)
    return float(padded), float(useful)


# ---------------------------------------------------------------------------
# per-dispatch profile hook (opt-in)
# ---------------------------------------------------------------------------


def set_dispatch_hook(fn: Optional[Callable]) -> None:
    """Install (or clear, with None) the per-dispatch profile callback:
    ``fn(name, seconds, meta)`` fires after every scheduler dispatch."""
    global _dispatch_hook
    _dispatch_hook = fn


def dispatch_event(name: str, seconds: float, **meta) -> None:
    """Report one finished dispatch to the opt-in hook (no-op otherwise)."""
    hook = _dispatch_hook
    if hook is not None:
        hook(name, seconds, meta)


# ---------------------------------------------------------------------------
# exposition
# ---------------------------------------------------------------------------


def render_prometheus() -> str:
    """Prometheus text block for the process-global jit/XLA counters —
    appended to the scheduler registry's exposition by ``/v1/metrics``."""
    with _lock:
        traces = sorted(_TRACE_COUNTS.items())
        events = sorted(_XLA_EVENTS.items())
    lines = [
        "# HELP jax_jit_tracings_total jit tracings per instrumented "
        "call-site (1 per compilation-cache miss)",
        "# TYPE jax_jit_tracings_total counter",
    ]
    lines.extend(render_exposition_line("jax_jit_tracings_total",
                                        [("site", site)], float(n))
                 for site, n in traces)
    if not traces:
        lines.append(render_exposition_line(
            "jax_jit_tracings_total", [("site", "none")], 0.0))
    lines.append("# HELP jax_monitoring_events_total raw jax.monitoring "
                 "event counts (best-effort corroboration)")
    lines.append("# TYPE jax_monitoring_events_total counter")
    lines.extend(render_exposition_line("jax_monitoring_events_total",
                                        [("event", ev)], float(n))
                 for ev, n in events)
    return "\n".join(lines) + "\n"
