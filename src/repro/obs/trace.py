"""Structured tracing for the serving stack (DESIGN.md §15.1).

A span is a plain dict — wire- and JSON-safe by construction, so spans
cross process boundaries (worker results), checkpoints (scheduler
snapshots), and HTTP (``/v1/trace``) without a codec of their own::

    {"trace_id": ..., "span_id": ..., "parent_id": ..., "name": ...,
     "attempt": 0, "t0": <unix s>, "t1": <unix s>, "attrs": {...}}

**Deterministic ids.**  ``span_id(trace_id, name, attempt)`` is a pure
hash: both ends of a dispatch derive the *same* id for the same logical
span without exchanging it.  The front end ships only
``{"trace_id", "attempt"}`` in the wire header plus the attempt number in
the task message; the worker re-derives its parent dispatch-span id from
those — which is what lets a re-dispatched (retried) task's worker spans
land under the retry's dispatch span rather than the first attempt's.

**Current span.**  A contextvar tracks the innermost open span so nested
``span(...)`` blocks parent automatically; cross-thread/process parents
are passed explicitly (``parent_id=``).

Timestamps are wall-clock (``time.time()``): worker and front-end spans
from the same machine line up on one timeline, which is how
``render_timeline`` shows queue-wait next to remote evaluation.
"""
from __future__ import annotations

import contextlib
import contextvars
import hashlib
import time
from typing import Dict, Iterable, List, Optional

__all__ = ["child_ctx", "current_span", "job_trace_id", "make_span",
           "render_timeline", "span", "span_id"]

_CURRENT: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "substrat_current_span", default=None)


def _digest(text: str) -> str:
    return hashlib.blake2s(text.encode("utf-8"), digest_size=8).hexdigest()


def job_trace_id(job_id: int) -> str:
    """Deterministic trace id of one served job."""
    return _digest(f"substrat-job/{int(job_id)}")


def span_id(trace_id: str, name: str, attempt: int = 0) -> str:
    """Deterministic span id — a pure function of (trace, name, attempt).

    The serving tier derives names from ``(job_id, phase, ...)``, so the
    same logical unit of work gets the same id on every run and on both
    sides of a process boundary (no id exchange needed)."""
    return _digest(f"{trace_id}/{name}#{int(attempt)}")


def current_span() -> Optional[dict]:
    """The innermost open span of this context, or None."""
    return _CURRENT.get()


def make_span(trace_id: str, name: str, t0: float, t1: float, *,
              parent_id: Optional[str] = None, attempt: int = 0,
              attrs: Optional[dict] = None) -> dict:
    """Build a closed span record without entering a context."""
    return {
        "trace_id": trace_id,
        "span_id": span_id(trace_id, name, attempt),
        "parent_id": parent_id,
        "name": name,
        "attempt": int(attempt),
        "t0": float(t0),
        "t1": float(t1),
        "attrs": dict(attrs or {}),
    }


@contextlib.contextmanager
def span(sink: Optional[List[dict]], trace_id: str, name: str, *,
         attempt: int = 0, parent_id: Optional[str] = None, **attrs):
    """Open a span; on exit, close it and append to ``sink``.

    The parent defaults to the contextvar current span (same-context
    nesting); pass ``parent_id=`` explicitly when the parent lives in
    another process (the wire-propagated dispatch span).  The open span
    dict is yielded so callers can add attrs mid-flight."""
    if parent_id is None:
        parent = _CURRENT.get()
        parent_id = parent["span_id"] if parent is not None else None
    sp = make_span(trace_id, name, time.time(), 0.0,
                   parent_id=parent_id, attempt=attempt, attrs=attrs)
    token = _CURRENT.set(sp)
    try:
        yield sp
    except BaseException:
        sp["attrs"]["error"] = True
        raise
    finally:
        sp["t1"] = time.time()
        _CURRENT.reset(token)
        if sink is not None:
            sink.append(sp)


def child_ctx(trace_id: str, parent_name: str, attempt: int = 0) -> dict:
    """The propagation payload a wire header carries (DESIGN.md §15.2):
    enough for the remote end to re-derive its parent span id."""
    return {"trace_id": trace_id, "parent": parent_name,
            "attempt": int(attempt)}


def _tree(spans: Iterable[dict]):
    """(roots, children-by-parent) with deterministic t0-then-name order."""
    spans = sorted(spans, key=lambda s: (s["t0"], s["name"]))
    ids = {s["span_id"] for s in spans}
    kids: Dict[str, List[dict]] = {}
    roots = []
    for s in spans:
        p = s.get("parent_id")
        if p is not None and p in ids:
            kids.setdefault(p, []).append(s)
        else:
            roots.append(s)
    return roots, kids


def render_timeline(spans: Iterable[dict], width: int = 32) -> str:
    """ASCII per-trace timeline: nested spans with offset/duration bars.

    Offsets are relative to the earliest span start; the bar column scales
    to the whole trace, so queue-wait, retries, and worker-side work show
    up as visibly disjoint segments of one timeline."""
    spans = list(spans)
    if not spans:
        return "(no spans)"
    t_lo = min(s["t0"] for s in spans)
    t_hi = max(max(s["t1"], s["t0"]) for s in spans)
    total = max(t_hi - t_lo, 1e-9)
    roots, kids = _tree(spans)
    lines = []

    def emit(s, depth):
        lo = int(round((s["t0"] - t_lo) / total * (width - 1)))
        hi = int(round((max(s["t1"], s["t0"]) - t_lo) / total * (width - 1)))
        bar = " " * lo + "#" * max(hi - lo, 1)
        label = "  " * depth + s["name"]
        if s.get("attempt"):
            label += f" (retry #{s['attempt']})"
        extra = []
        for k in ("phase", "rung", "worker", "outcome", "mode"):
            if k in s["attrs"]:
                extra.append(f"{k}={s['attrs'][k]}")
        lines.append(
            f"{label:<34} |{bar:<{width}}| "
            f"+{s['t0'] - t_lo:7.3f}s {s['t1'] - s['t0']:8.3f}s"
            + (f"  {' '.join(extra)}" if extra else ""))
        for c in kids.get(s["span_id"], ()):
            emit(c, depth + 1)

    for r in roots:
        emit(r, 0)
    return "\n".join(lines)
