"""Public flash-attention op with backend switch (pallas TPU target vs
pure-jnp XLA path used on CPU / in the dry-run)."""
from __future__ import annotations

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def flash_attention(q, k, v, *, causal=True, use_pallas=False, interpret=True):
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, interpret=interpret)
    return attention_ref(q, k, v, causal=causal)
