"""Pallas TPU kernel: causal GQA flash attention (train/prefill hotspot).

Online-softmax tiling: grid (batch*q_heads, Sq/TQ, Skv/TK) with the KV tile
axis innermost (sequential on TPU).  Running max / sum / accumulator live in
VMEM scratch; fully-masked KV tiles short-circuit via pl.when.  GQA is
expressed in the K/V index_map (query head h reads kv head h // group_size)
— no K/V duplication in HBM.

VMEM per step ~ TQ*hd + 2*TK*hd + TQ*TK floats; defaults (TQ=TK=128,
hd<=256) stay well under the ~16 MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip tiles strictly above the diagonal
    run = True
    if causal:
        run = (kv_idx * block_k) <= (q_idx * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (TQ, hd)
        k = k_ref[0].astype(jnp.float32)                # (TK, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (TQ, TK)
        if causal:
            qpos = q_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            kpos = kv_idx * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,     # (B, Sq, H, hd)
    k: jax.Array,     # (B, Skv, K, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Skv, Kh, _ = k.shape
    group = H // Kh
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, "pad seq to block multiple"

    # layout: (B*H, S, hd) with heads folded into the grid's first axis
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kh, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kh, Skv, hd)

    def kv_index(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * Kh + h // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=hd ** -0.5, causal=causal,
            block_q=block_q, block_k=block_k,
        ),
        grid=(B * H, Sq // block_q, Skv // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
