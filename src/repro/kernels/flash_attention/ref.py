"""Pure-jnp oracle for flash attention (GQA, optional causal)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


@functools.partial(jax.jit, static_argnames=("causal",))
def attention_ref(q, k, v, *, causal: bool = True):
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    logits *= hd ** -0.5
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
