"""Pure-jnp oracle for the masked histogram kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["masked_histogram_ref", "entropy_from_hist"]


@functools.partial(jax.jit, static_argnames=("bins",))
def masked_histogram_ref(codes: jax.Array, weights: jax.Array, bins: int) -> jax.Array:
    """hist[m, b] = sum_n w[n] * [codes[n, m] == b], via flat scatter-add."""
    N, M = codes.shape
    flat = (codes + jnp.arange(M, dtype=codes.dtype)[None, :] * bins).ravel()
    w = jnp.broadcast_to(weights.astype(jnp.float32)[:, None], (N, M)).ravel()
    return jnp.zeros((M * bins,), jnp.float32).at[flat].add(w).reshape(M, bins)


def entropy_from_hist(hist: jax.Array) -> jax.Array:
    total = jnp.maximum(hist.sum(-1, keepdims=True), 1e-12)
    p = hist / total
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0), -1)
