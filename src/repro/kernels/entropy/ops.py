"""Public ops for the entropy kernel: jit'd wrappers with a backend switch.

``column_entropy_masked(codes, weights, bins)`` is the weighted-histogram
primitive: per-column entropy of the weighted (membership-masked) rows.
``population_histogram`` is the Gen-DST batch primitive: per-candidate
(M, B) histograms for a whole GA population in one call — on the Pallas
path the population axis is folded into the column axis, so the entire
population recompute is a single ``masked_histogram_pallas`` launch.

Backend selection:
  * ``backend="jnp"``     — XLA scatter-add reference (`ref.py`); the
    production path on CPU and the correctness oracle everywhere.
  * ``backend="pallas"``  — the MXU one-hot-contraction kernel
    (`kernel.py`).  On TPU pass ``interpret=False``; CPU tests and the
    default ``interpret=None`` (auto) run the kernel body in interpret
    mode, which validates semantics but is slow — never the CPU prod path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import masked_histogram_pallas
from .ref import masked_histogram_ref, entropy_from_hist

__all__ = [
    "masked_histogram",
    "column_entropy_masked",
    "population_histogram",
    "resolve_interpret",
]


def resolve_interpret(interpret=None) -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpreted elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def masked_histogram(
    codes: jax.Array,
    weights: jax.Array,
    bins: int,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    if use_pallas:
        return masked_histogram_pallas(codes, weights, bins, interpret=interpret)
    return masked_histogram_ref(codes, weights, bins)


def column_entropy_masked(
    codes: jax.Array,
    weights: jax.Array,
    bins: int,
    **kw,
) -> jax.Array:
    """(M,) per-column entropy of the masked rows."""
    return entropy_from_hist(masked_histogram(codes, weights, bins, **kw))


@functools.partial(jax.jit, static_argnames=("bins", "backend", "interpret"))
def population_histogram(
    sub_codes: jax.Array,        # (P, n, M) int32 — gathered candidate subsets
    bins: int,
    *,
    backend: str = "jnp",
    interpret: bool | None = None,   # None = auto: compiled on TPU
) -> jax.Array:
    """Per-candidate histograms: out[p, m, b] = |{i : sub_codes[p, i, m] == b}|.

    The Pallas route reshapes the population into the column axis —
    (P, n, M) -> (n, P*M) — so one kernel launch covers every candidate
    (each candidate's columns are independent; uniform weights).
    """
    P, n, M = sub_codes.shape
    ones = jnp.ones((n,), jnp.float32)
    if backend == "pallas":
        flat = sub_codes.transpose(1, 0, 2).reshape(n, P * M)
        hist = masked_histogram_pallas(
            flat, ones, bins, interpret=resolve_interpret(interpret)
        )
        return hist.reshape(P, M, bins)
    if backend != "jnp":
        raise ValueError(f"unknown histogram backend: {backend!r}")
    return jax.vmap(lambda c: masked_histogram_ref(c, ones, bins))(sub_codes)
