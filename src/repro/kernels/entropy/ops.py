"""Public ops for the entropy kernel: jit'd wrappers with a backend switch.

``column_entropy_masked(codes, weights, bins)`` is the Gen-DST fitness
primitive: per-column entropy of the weighted (membership-masked) rows.
On TPU set ``use_pallas=True, interpret=False``; CPU tests run the kernel
body in interpret mode against the ref oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import masked_histogram_pallas
from .ref import masked_histogram_ref, entropy_from_hist

__all__ = ["masked_histogram", "column_entropy_masked"]


def masked_histogram(
    codes: jax.Array,
    weights: jax.Array,
    bins: int,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    if use_pallas:
        return masked_histogram_pallas(codes, weights, bins, interpret=interpret)
    return masked_histogram_ref(codes, weights, bins)


def column_entropy_masked(
    codes: jax.Array,
    weights: jax.Array,
    bins: int,
    **kw,
) -> jax.Array:
    """(M,) per-column entropy of the masked rows."""
    return entropy_from_hist(masked_histogram(codes, weights, bins, **kw))
