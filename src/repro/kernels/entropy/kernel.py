"""Pallas TPU kernel: masked per-column histogram (Gen-DST fitness hotspot).

Computes ``hist[m, b] = sum_n w[n] * (codes[n, m] == b)`` without ever
materializing the (N, B) one-hot in HBM: each grid step loads a
(TN rows × TM cols) code tile + TN weights into VMEM, forms the one-hot
there, and contracts it against the weights with one (1, TN) x (TN, TM*B)
matmul (MXU work), accumulating into the (TM, B) output block.

Grid: (M/TM, N/TN) — the row-tile axis is innermost (sequential on TPU), so
the output block accumulates correctly across row tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["masked_histogram_kernel", "masked_histogram_pallas"]


def masked_histogram_kernel(codes_ref, w_ref, out_ref, *, bins: int):
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                       # (TN, TM) int32
    w = w_ref[...].astype(jnp.float32)           # (TN,)
    tn, tm = codes.shape
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (tn, tm, bins), 2)
    onehot = (codes[:, :, None] == iota_b).astype(jnp.float32)   # (TN, TM, B)
    contrib = jnp.dot(
        w[None, :], onehot.reshape(tn, tm * bins),
        preferred_element_type=jnp.float32,
    ).reshape(tm, bins)
    out_ref[...] += contrib


@functools.partial(
    jax.jit, static_argnames=("bins", "tile_n", "tile_m", "interpret")
)
def masked_histogram_pallas(
    codes: jax.Array,            # (N, M) int32
    weights: jax.Array,          # (N,) float
    bins: int,
    *,
    tile_n: int = 1024,
    tile_m: int = 8,
    interpret: bool = True,      # CPU validation default; False on real TPU
) -> jax.Array:
    N, M = codes.shape
    tile_n = min(tile_n, max(8, N))
    tile_m = min(tile_m, M)
    pad_n = (-N) % tile_n
    pad_m = (-M) % tile_m
    codes_p = jnp.pad(codes, ((0, pad_n), (0, pad_m)))
    w_p = jnp.pad(weights.astype(jnp.float32), (0, pad_n))  # padded rows: w=0
    Np, Mp = codes_p.shape

    out = pl.pallas_call(
        functools.partial(masked_histogram_kernel, bins=bins),
        grid=(Mp // tile_m, Np // tile_n),
        in_specs=[
            pl.BlockSpec((tile_n, tile_m), lambda m, n: (n, m)),
            pl.BlockSpec((tile_n,), lambda m, n: (n,)),
        ],
        out_specs=pl.BlockSpec((tile_m, bins), lambda m, n: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, bins), jnp.float32),
        interpret=interpret,
    )(codes_p, w_p)
    return out[:M]
