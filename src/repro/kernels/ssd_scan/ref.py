"""Pure-jnp oracle for the SSD scan: the naive per-timestep recurrence."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["ssd_scan_ref"]


@jax.jit
def ssd_scan_ref(x, dt, a, bm, cm):
    """x (BH,S,P), dt (BH,S), a (BH,), bm/cm (BH,S,N) -> y (BH,S,P).

    h_t = exp(dt_t a) h_{t-1} + dt_t x_t B_t^T ;  y_t = h_t C_t."""
    BH, S, P = x.shape
    N = bm.shape[-1]

    def per_head(xh, dth, ah, bh, ch):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = h * jnp.exp(dtt * ah) + (dtt * xt)[:, None] * bt[None, :]
            return h, h @ ct
        _, ys = jax.lax.scan(
            step, jnp.zeros((P, N), jnp.float32),
            (xh.astype(jnp.float32), dth.astype(jnp.float32),
             bh.astype(jnp.float32), ch.astype(jnp.float32)),
        )
        return ys

    return jax.vmap(per_head)(x, dt, a, bm, cm).astype(x.dtype)
