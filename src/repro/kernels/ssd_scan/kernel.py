"""Pallas TPU kernel: Mamba-2 SSD chunked scan (the SSM-family hotspot).

Grid (B*H, S/Q) with the chunk axis innermost (sequential on TPU): the
(P, N) inter-chunk state lives in VMEM scratch and is carried across chunk
steps; within a chunk the output is the masked decay-weighted quadratic
contraction (two (Q,Q)x(Q,P) MXU matmuls) — HBM sees only the chunk inputs
and outputs, never the (Q,Q) attention-like intermediates.

Per-head layout (the ops.py wrapper folds (B, H) and broadcasts groups):
  x  (BH, S, P)   dt (BH, S)   A (BH,)   Bm/Cm (BH, S, N)  ->  y (BH, S, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas"]


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_scr, *, block_q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)          # scalar (negative)
    bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    la = jnp.cumsum(dt * a)                   # (Q,) log-decay
    u = x * dt[:, None]                       # discretized input

    # intra-chunk: masked decay-weighted quadratic form
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)     # (Q, Q)
    decay = jnp.exp(la[:, None] - la[None, :])
    qq = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 1)
    att = jnp.where(qq, cb * decay, 0.0)
    y = jnp.dot(att, u, preferred_element_type=jnp.float32)        # (Q, P)

    # inter-chunk: contribution of the carried state
    h = h_scr[...]                                                  # (P, N)
    y += jnp.dot(cm * jnp.exp(la)[:, None], h.T,
                 preferred_element_type=jnp.float32)

    # state update: h' = h * exp(la_Q) + sum_t exp(la_Q - la_t) u_t B_t^T
    seg = jnp.exp(la[-1] - la)                                      # (Q,)
    h_scr[...] = h * jnp.exp(la[-1]) + jnp.dot(
        u.T, bm * seg[:, None], preferred_element_type=jnp.float32
    )
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,     # (BH, S, P)
    dt: jax.Array,    # (BH, S)
    a: jax.Array,     # (BH,) negative decay rates
    bm: jax.Array,    # (BH, S, N)
    cm: jax.Array,    # (BH, S, N)
    *,
    block_q: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, S, P = x.shape
    N = bm.shape[-1]
    block_q = min(block_q, S)
    assert S % block_q == 0, "pad sequence to a chunk multiple"

    return pl.pallas_call(
        functools.partial(_ssd_kernel, block_q=block_q),
        grid=(BH, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, block_q), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
            pl.BlockSpec((1, block_q, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, block_q, N), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
