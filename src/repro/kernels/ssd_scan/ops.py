"""Public SSD-scan op: reshapes model-layout tensors to the kernel's
per-head layout and broadcasts B/C groups; backend switch as elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_pallas
from .ref import ssd_scan_ref

__all__ = ["ssd_scan"]


def ssd_scan(x, dt, a, bm, cm, *, use_pallas=False, interpret=True,
             block_q: int = 128):
    """Model layout: x (B,S,H,P), dt (B,S,H), a (H,), bm/cm (B,S,G,N)."""
    B, S, H, P = x.shape
    G, N = bm.shape[2], bm.shape[3]
    rep = H // G
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    af = jnp.tile(a, B)
    bmh = jnp.repeat(bm, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    cmh = jnp.repeat(cm, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    if use_pallas:
        y = ssd_scan_pallas(xf, dtf, af, bmh, cmh, block_q=block_q,
                            interpret=interpret)
    else:
        y = ssd_scan_ref(xf, dtf, af, bmh, cmh)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
