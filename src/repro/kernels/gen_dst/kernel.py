"""Pallas TPU kernel: fused Gen-DST generation step (DESIGN.md §16).

One launch per mutation-only generation replaces the scatter-add
``_row_delta`` + ``_counts_fitness`` round trip of the jnp path: each grid
step holds a (TP candidates × M columns × B bins) slab of the population
count tensor in VMEM and, without writing intermediates back to HBM,

  1. applies the one-row mutation delta as a one-hot compare against a
     bin iota (``counts += w * (onehot(new) - onehot(old))`` — VPU work,
     no scatter, exact ±1.0 adds on integer-valued f32 counts), and
  2. reduces the updated slab straight to the masked-entropy fitness
     (normalize → p·log2 p → column-mask average → -|f_d - F(D)|).

The jnp path reads the (P, M, B) counts from HBM twice per generation
(scatter-add pass + entropy pass) and round-trips the updated tensor in
between; the fused kernel reads it once, writes it once (in-place via
``input_output_aliases``), and emits the (P,) fitness from the same
residency.  Crossover (full-recompute) generations route the histogram
rebuild through ``kernels/entropy``'s MXU path and then this kernel with
``applied = 0`` — a zero delta — so *every* generation's fitness comes
from one code path.

Grid: (P/TP,) over candidate tiles; M and B stay whole inside a block
(the per-candidate (M, B) histogram is small — Gen-DST datasets have
dozens of columns and B ≤ 256 bins — so a slab of TP candidates fits
VMEM comfortably; see §16.2 for the budget arithmetic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_delta_fitness_kernel", "fused_delta_fitness_pallas"]


def fused_delta_fitness_kernel(
    counts_ref,      # (TP, M, B) f32
    oldc_ref,        # (TP, M) int32
    newc_ref,        # (TP, M) int32
    w_ref,           # (TP, 1) f32 — 1.0 where the row mutation fired
    cols_ref,        # (TP, M) f32 column mask
    fref_ref,        # (1, 1) f32 — F(D)
    counts_out_ref,  # (TP, M, B) f32, aliased onto counts_ref's buffer
    fit_ref,         # (TP, 1) f32
    *,
    bins: int,
):
    counts = counts_ref[...]
    oldc = oldc_ref[...]
    newc = newc_ref[...]
    w = w_ref[...]                                   # (TP, 1)
    cm = cols_ref[...]                               # (TP, M)
    f_ref = fref_ref[0, 0]

    tp, m = oldc.shape
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (tp, m, bins), 2)
    delta = ((newc[:, :, None] == iota_b).astype(jnp.float32)
             - (oldc[:, :, None] == iota_b).astype(jnp.float32))
    counts = counts + w[:, :, None] * delta          # exact ±1.0 adds

    total = jnp.maximum(jnp.sum(counts, axis=-1, keepdims=True), 1e-12)
    p = counts / total
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0),
                 axis=-1)                            # (TP, M)
    f_d = jnp.sum(h * cm, axis=-1) / jnp.maximum(jnp.sum(cm, axis=-1), 1.0)

    counts_out_ref[...] = counts
    fit_ref[...] = (-jnp.abs(f_d - f_ref))[:, None]


@functools.partial(
    jax.jit, static_argnames=("bins", "tile_p", "interpret")
)
def fused_delta_fitness_pallas(
    counts: jax.Array,        # (P, M, B) f32
    old_codes: jax.Array,     # (P, M) int32
    new_codes: jax.Array,     # (P, M) int32
    applied: jax.Array,       # (P,) bool/f32
    col_mask: jax.Array,      # (P, M) bool
    f_ref: jax.Array,         # scalar f32
    *,
    bins: int,
    tile_p: int = 8,
    interpret: bool = True,   # CPU validation default; False on real TPU
):
    P, M, B = counts.shape
    assert B == bins
    tile_p = min(tile_p, max(1, P))
    pad_p = (-P) % tile_p
    # padded candidates: zero counts / zero mask / zero delta weight — their
    # fitness lane is computed but sliced off below
    counts_p = jnp.pad(counts, ((0, pad_p), (0, 0), (0, 0)))
    oldc_p = jnp.pad(old_codes, ((0, pad_p), (0, 0)))
    newc_p = jnp.pad(new_codes, ((0, pad_p), (0, 0)))
    w_p = jnp.pad(applied.astype(jnp.float32), (0, pad_p))[:, None]
    cols_p = jnp.pad(col_mask.astype(jnp.float32), ((0, pad_p), (0, 0)))
    Pp = P + pad_p

    counts_out, fit = pl.pallas_call(
        functools.partial(fused_delta_fitness_kernel, bins=bins),
        grid=(Pp // tile_p,),
        in_specs=[
            pl.BlockSpec((tile_p, M, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_p, M), lambda i: (i, 0)),
            pl.BlockSpec((tile_p, M), lambda i: (i, 0)),
            pl.BlockSpec((tile_p, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_p, M), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p, M, B), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_p, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Pp, M, B), jnp.float32),
            jax.ShapeDtypeStruct((Pp, 1), jnp.float32),
        ],
        # the count tensor is updated in place: one HBM read + one write
        # per generation instead of the jnp path's read/write/read
        input_output_aliases={0: 0},
        interpret=interpret,
    )(counts_p, oldc_p, newc_p, w_p, cols_p,
      jnp.asarray(f_ref, jnp.float32).reshape(1, 1))
    return counts_out[:P], fit[:P, 0]
