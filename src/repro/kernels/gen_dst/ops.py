"""Public ops for the fused Gen-DST generation kernel (DESIGN.md §16).

``fused_delta_fitness`` is the one primitive ``_gen_dst_core`` calls per
generation on the ``backend="pallas_fused"`` path: delta-update the
per-candidate (M, B) count tensor after a one-row mutation and reduce it
to the masked-entropy fitness, in a single launch.  Crossover generations
pass ``applied = 0`` (zero delta), so the same launch also serves as the
fitness reduction over freshly recomputed histograms.

Backend selection mirrors ``kernels/entropy/ops.py``:
  * ``backend="jnp"``          — scatter-add + entropy reference
    (`ref.py`); the production CPU path and the bit-level oracle.
  * ``backend="pallas_fused"`` — the VMEM-resident fused kernel
    (`kernel.py`).  On TPU pass ``interpret=False``; CPU tests and the
    default ``interpret=None`` (auto) run the kernel body in interpret
    mode, which validates semantics but is slow — never the CPU prod
    path.

Leading axes: inputs may carry any leading shape (Gen-DST calls with
``(islands, phi, ...)``); everything is flattened to one candidate axis
for the launch and restored on return.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...obs.jaxprof import note_trace
from ..entropy.ops import resolve_interpret
from .kernel import fused_delta_fitness_pallas
from .ref import fused_delta_fitness_ref

__all__ = ["fused_delta_fitness", "resolve_interpret"]


@functools.partial(
    jax.jit, static_argnames=("backend", "interpret", "tile_p")
)
def fused_delta_fitness(
    counts: jax.Array,        # (..., M, B) f32 per-candidate histograms
    old_codes: jax.Array,     # (..., M) int32 codes of the evicted row
    new_codes: jax.Array,     # (..., M) int32 codes of the inserted row
    applied: jax.Array,       # (...,) bool — row mutations that fired
    col_mask: jax.Array,      # (..., M) bool column membership
    f_ref: jax.Array,         # scalar F(D)
    *,
    backend: str = "jnp",
    interpret: bool | None = None,   # None = auto: compiled on TPU
    tile_p: int = 8,
):
    """``(counts', fitness)``: one fused Gen-DST generation update.

    ``counts'[p]`` is ``counts[p]`` with row ``old→new`` swapped where
    ``applied[p]``; ``fitness[p] = -|F(d_p) - F(D)|`` from the updated
    counts under ``col_mask[p]``.
    """
    note_trace("kernels.gen_dst.fused_delta_fitness")
    lead = old_codes.shape[:-1]
    M, B = counts.shape[-2:]
    cf = counts.reshape(-1, M, B)
    of = old_codes.reshape(-1, M)
    nf = new_codes.reshape(-1, M)
    af = applied.reshape(-1)
    mf = col_mask.reshape(-1, M)
    if backend == "pallas_fused":
        c2, fit = fused_delta_fitness_pallas(
            cf, of, nf, af, mf, f_ref, bins=B, tile_p=tile_p,
            interpret=resolve_interpret(interpret),
        )
    elif backend == "jnp":
        c2, fit = fused_delta_fitness_ref(cf, of, nf, af, mf, f_ref)
    else:
        raise ValueError(f"unknown fused Gen-DST backend: {backend!r}")
    return c2.reshape(*lead, M, B), fit.reshape(lead)
