"""Pure-jnp oracle for the fused Gen-DST generation kernel.

Semantics are exactly the two steps the kernel fuses — the scatter-add
row-delta update (``gen_dst._row_delta``) followed by the masked-entropy
fitness (``gen_dst._counts_fitness``) — written with the identical
operation sequence so the jnp path stays a *bit-level* oracle for the
interpret-mode kernel on CPU (same adds of exact small integers, same
reduction axes/order, same eps clamps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["fused_delta_fitness_ref"]


@functools.partial(jax.jit, static_argnames=())
def fused_delta_fitness_ref(
    counts: jax.Array,        # (P, M, B) f32 per-candidate histograms
    old_codes: jax.Array,     # (P, M) int32 codes of the evicted row
    new_codes: jax.Array,     # (P, M) int32 codes of the inserted row
    applied: jax.Array,       # (P,) bool/f32 — row mutations that fired
    col_mask: jax.Array,      # (P, M) bool column membership
    f_ref: jax.Array,         # scalar F(D)
):
    """Delta-update counts, then masked-entropy fitness; returns
    ``(counts', fitness)`` with ``fitness[p] = -|F(d_p) - F(D)|``."""
    P, M = old_codes.shape
    w = applied.astype(jnp.float32)[:, None]          # (P, 1)
    ai = jnp.arange(P)[:, None]
    aj = jnp.arange(M)[None, :]
    counts = counts.at[ai, aj, old_codes].add(-w)
    counts = counts.at[ai, aj, new_codes].add(w)

    total = jnp.maximum(counts.sum(axis=-1, keepdims=True), 1e-12)
    p = counts / total
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0),
                 axis=-1)                             # (P, M)
    cmf = col_mask.astype(jnp.float32)
    f_d = jnp.sum(h * cmf, axis=-1) / jnp.maximum(cmf.sum(axis=-1), 1.0)
    return counts, -jnp.abs(f_d - f_ref)
