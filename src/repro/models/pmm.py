"""Sharding-aware matmul with a custom VJP (Megatron-SP semantics).

Observed in the llama3-405b dry-run HLO before this wrapper existed: with
sequence-sharded residuals, GSPMD kept *activations* seq-sharded through
every projection and instead all-gathered the full (f32-normalized) weight
per matmul per layer per microbatch — ~14 TB of ICI traffic per step — and
produced weight grads as full-shape f32 partials that were all-reduced
before sharding.

``matmul`` pins the production layout explicitly:

  forward   x --(gather seq)--> dot with TP-sharded W --> out TP-sharded
            (pure 'bsd' outputs are constrained back to the seq-sharded
            residual layout => partial sums lower as reduce-scatter);
  backward  dx follows the same rule; dW contracts TP-sharded operands so
            the local tile is already TP-sharded, is cast to the weight
            dtype (bf16 wire), and lands in the parameter's (FSDP x TP)
            layout via reduce-scatter over the data axis;
  weights   are explicitly un-sharded only over 'data' (FSDP gather) in
            their storage dtype — never in the CPU backend's f32
            normalization dtype.

``meta`` = (dw_spec, data_size, model_size, act_spec) — the weight's
PartitionSpec-tuple, mesh axis sizes for divisibility checks, and the
residual-activation spec (or None).  ``meta=None`` => plain einsum autodiff.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["matmul"]


def _split_subs(subscripts: str):
    ins, out = subscripts.split("->")
    a, b = ins.split(",")
    return a, b, out


def _letter_ax(bsub: str, dw_spec) -> dict:
    return {letter: ax for letter, ax in zip(bsub, dw_spec) if ax == "model"}


def _tp_spec(sub: str, shape, letter_ax, data_size: int):
    """'model' on dims mapped to a TP-sharded dW dim; 'data' on the leading
    batch dim (divisibility-checked); None elsewhere."""
    entries = []
    for i, letter in enumerate(sub):
        if letter_ax.get(letter) == "model":
            entries.append("model")
        elif i == 0 and data_size > 1 and shape[0] % data_size == 0:
            entries.append("data")
        else:
            entries.append(None)
    return P(*entries)


def _constrain_act(t, sub: str, letter_ax, meta):
    """TP spec if the tensor carries a TP letter; residual act spec if not."""
    dw_spec, data_size, model_size, act_spec = meta
    if any(letter_ax.get(c) == "model" for c in sub):
        return jax.lax.with_sharding_constraint(
            t, _tp_spec(sub, t.shape, letter_ax, data_size)
        )
    if act_spec is not None and len(act_spec) == t.ndim:
        return jax.lax.with_sharding_constraint(t, P(*act_spec))
    return t


def _unshard_data(w, meta):
    """FSDP weight gather in the storage dtype (TP sharding kept)."""
    if meta is None:
        return w
    gspec = tuple(ax if ax == "model" else None for ax in meta[0])
    return jax.lax.with_sharding_constraint(w, P(*gspec))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def matmul(x, w, subscripts: str, meta: Optional[Tuple] = None):
    """einsum(subscripts, x, w) with production sharding semantics.

    With meta set, dots emit ``preferred_element_type = compute dtype`` so
    GSPMD's partial-sum collectives move bf16 (the MXU still accumulates in
    f32 internally on TPU); activations are explicitly gathered in bf16
    before the dot rather than post-float-normalization in f32."""
    if meta is None:
        return jnp.einsum(subscripts, x, w)
    a, b, o = _split_subs(subscripts)
    la = _letter_ax(b, meta[0])
    if la:
        # gather the (small) activation over seq/model for the TP matmul
        x = jax.lax.with_sharding_constraint(
            x, _tp_spec(a, x.shape, la, meta[1])
        )
    out = jnp.einsum(subscripts, x, _unshard_data(w, meta),
                     preferred_element_type=x.dtype)
    return _constrain_act(out, o, la, meta)


def _fwd(x, w, subscripts, meta):
    return matmul(x, w, subscripts, meta), (x, w)


def _bwd(subscripts, meta, res, g):
    x, w = res
    a, b, out = _split_subs(subscripts)
    g = g.astype(x.dtype)
    pet = {} if meta is None else {"preferred_element_type": x.dtype}
    # dx: contract g with the (storage-dtype, FSDP-gathered) weight
    dx = jnp.einsum(f"{out},{b}->{a}", g,
                    _unshard_data(w, meta).astype(g.dtype), **pet)

    if meta is not None:
        la = _letter_ax(b, meta[0])
        dx = _constrain_act(dx, a, la, meta)
        if la:
            g = _constrain_act(g, out, la, meta)
            # x fully gathered on non-TP dims for the dW contraction
            x = jax.lax.with_sharding_constraint(
                x, _tp_spec(a, x.shape, la, meta[1])
            )
    # dW: local tile already TP-sharded; bf16 wire; data-axis reduce-scatter
    dw = jnp.einsum(f"{a},{out}->{b}", x, g, **pet).astype(w.dtype)
    if meta is not None and any(ax for ax in meta[0]):
        dw = jax.lax.with_sharding_constraint(dw, P(*meta[0]))
    return dx, dw


matmul.defvjp(_fwd, _bwd)
