"""Shared neural building blocks: norms, rotary embeddings, attention, MLP.

Conventions
-----------
* All weights carry explicit semantic axis names via the ``LOGICAL_AXES``
  table in ``repro/distributed/sharding.py`` (keyed by parameter leaf name).
* Attention weights use unflattened head layout: wq (D, H, hd), wo (H, hd, D)
  — this keeps the TP axis (heads) explicit for the SPMD partitioner.
* KV caches are (B, S_max, K, hd) per layer, time-indexed by ``pos``.
* Compute runs in cfg.dtype (bf16), accumulation and softmax in f32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .pmm import matmul as _pmm


def _sanitize_dw_spec(cfg: ModelConfig, w, dw_spec):
    """Drop spec axes whose mesh size doesn't divide the weight dim."""
    sizes = {"data": cfg.mesh_data_size, "model": cfg.mesh_model_size}
    out = []
    for dim, ax in zip(w.shape, dw_spec):
        sz = sizes.get(ax, 1) if isinstance(ax, str) else 1
        out.append(ax if (ax is not None and sz > 1 and dim % sz == 0) else None)
    return tuple(out)


def _proj(x, w, subscripts: str, cfg: ModelConfig, dw_spec):
    """Weight projection: custom-VJP matmul with grad sharding when enabled."""
    if cfg.grad_shard:
        meta = (_sanitize_dw_spec(cfg, w, dw_spec),
                cfg.mesh_data_size, cfg.mesh_model_size,
                cfg.act_shard_spec or None)
        return _pmm(x, w.astype(x.dtype), subscripts, meta)
    return jnp.einsum(subscripts, x, w.astype(x.dtype))

__all__ = [
    "rms_norm", "layer_norm", "rotary", "apply_rope", "init_attn", "attention",
    "init_mlp", "mlp", "init_dense_layer", "dense_layer",
    "KVCache", "sinusoidal_pos",
]


def rms_norm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = x32.var(axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def sinusoidal_pos(S: int, D: int, dtype=jnp.float32):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(D // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)


def rotary(positions, head_dim: int, theta: float):
    """cos/sin tables for given integer positions: (..., head_dim//2)."""
    dim = jnp.arange(head_dim // 2, dtype=jnp.float32)
    inv_freq = 1.0 / (theta ** (2 * dim / head_dim))
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, K, hd)
    v: jax.Array


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig, d_model: Optional[int] = None):
    D = d_model or cfg.d_model
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "q": jax.random.normal(kq, (D, H, hd), cfg.params_dtype) * s,
        "k": jax.random.normal(kk, (D, K, hd), cfg.params_dtype) * s,
        "v": jax.random.normal(kv, (D, K, hd), cfg.params_dtype) * s,
        "out": jax.random.normal(ko, (H, hd, D), cfg.params_dtype) * ((H * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.params_dtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.params_dtype)
    return p


def _sdpa_q_chunked(q, k, v, *, causal, q_offset, kv_len, chunk: int):
    """Exact attention with the query axis processed in chunks (lax.map):
    bounds live score memory to (B, H, chunk, Skv) — the XLA-level analogue
    of the Pallas flash kernel, used for long prefill (no grad needed)."""
    B, Sq, H, hd = q.shape
    nc = Sq // chunk
    qc = jnp.moveaxis(q.reshape(B, nc, chunk, H, hd), 1, 0)

    def one(args):
        i, qq = args
        return _sdpa(qq, k, v, causal=causal, q_offset=q_offset + i * chunk,
                     kv_len=kv_len, q_chunk=None)

    outs = jax.lax.map(one, (jnp.arange(nc), qc))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)


def _sdpa(q, k, v, *, causal: bool, q_offset=0, kv_len: Optional[jax.Array] = None,
          q_chunk: Optional[int] = None):
    """Grouped-query scaled dot-product attention, f32 softmax.

    q: (B, Sq, H, hd);  k, v: (B, Skv, K, hd).  H = G*K.
    ``q_offset``: absolute position of q[0] (for causal masking vs a cache).
    ``kv_len``: optional valid prefix length of k/v (cache may be padded).
    """
    B, Sq, H, hd = q.shape
    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        return _sdpa_q_chunked(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len, chunk=q_chunk
        )
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    # pet=f32 keeps the operands bf16 in HLO (no hoisted full-cache upcast)
    # while accumulating the scores in f32
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits *= hd ** -0.5
    Skv = k.shape[1]
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Skv)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(Skv)[None, :] < kv_len[:, None]      # (B, Skv)
        logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return o.reshape(B, Sq, H, hd)


def attention(
    p,
    x,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    pos: Optional[jax.Array] = None,        # scalar: write offset into cache
    kv_x: Optional[jax.Array] = None,       # cross-attention source
    use_rope: bool = True,
    precomputed_kv: Optional[KVCache] = None,
    collect_kv: bool = False,               # prefill: return fresh K/V as cache
):
    """Multi-purpose attention: self/cross, train/prefill/decode.

    Returns (out, new_cache_or_None).
    """
    B, S, D = x.shape
    q = _proj(x, p["q"], "bsd,dhk->bshk", cfg, ("data", "model", None))
    if precomputed_kv is not None:          # cross-attn with cached enc K/V
        k, v = precomputed_kv.k, precomputed_kv.v
        new_cache = None
    else:
        src = x if kv_x is None else kv_x
        kv_spec = ("data", None, None)
        k = _proj(src, p["k"], "bsd,dhk->bshk", cfg, kv_spec)
        v = _proj(src, p["v"], "bsd,dhk->bshk", cfg, kv_spec)
        new_cache = None

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if precomputed_kv is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if use_rope and precomputed_kv is None and kv_x is None:
        offset = 0 if pos is None else pos
        qpos = jnp.arange(S) + offset
        cos, sin = rotary(qpos, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    # Pallas flash-attention backend (TPU target; interpret mode on CPU):
    # the no-cache causal self-attention path (training fwd)
    if (
        cfg.attn_impl.startswith("pallas") and cache is None
        and precomputed_kv is None and kv_x is None and causal
        and S % 128 == 0 and k.shape[1] % 128 == 0
    ):
        from ..kernels.flash_attention.kernel import flash_attention_pallas
        o = flash_attention_pallas(
            q, k, v, causal=True,
            interpret=(cfg.attn_impl == "pallas_interpret"),
        )
        out = _proj(o, p["out"], "bshk,hkd->bsd", cfg, ("model", None, "data"))
        return out, new_cache

    kv_len = None
    q_offset = 0
    if cache is not None and precomputed_kv is None:
        # write the new K/V into the cache at ``pos`` and attend to the prefix
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), pos, axis=1)
        new_cache = KVCache(k_cache, v_cache)
        k, v = k_cache, v_cache
        kv_len = jnp.broadcast_to(pos + S, (B,))
        q_offset = pos

    if collect_kv and cache is None and precomputed_kv is None:
        # prefill: the freshly-computed (post-rope) K/V *are* the cache —
        # no zero-init buffers, no dynamic-update-slice copies.  Cache dtype
        # follows the compute dtype (bf16 in production, f32 in exact tests).
        cache_dtype = jnp.bfloat16 if x.dtype == jnp.bfloat16 else x.dtype
        new_cache = KVCache(k.astype(cache_dtype), v.astype(cache_dtype))

    # long-prefill / encoder paths (no grad): bound score memory by chunking
    # the query axis — the XLA analogue of the flash kernel's tiling.
    q_chunk = None
    skv = k.shape[1]
    if S * skv >= 2 ** 26 and (collect_kv or cache is not None or not causal
                               or precomputed_kv is not None):
        target = max(128, 2 ** 23 // skv)
        for cand in (target, 2048, 1024, 512, 256, 128):
            if cand <= target and S % cand == 0 and S > cand:
                q_chunk = cand
                break

    o = _sdpa(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
              q_chunk=q_chunk)
    out = _proj(o, p["out"], "bshk,hkd->bsd", cfg, ("model", None, "data"))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_model: Optional[int] = None, d_ff: Optional[int] = None):
    D = d_model or cfg.d_model
    F = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    s_in, s_out = D ** -0.5, F ** -0.5
    p = {
        "up": jax.random.normal(ku, (D, F), cfg.params_dtype) * s_in,
        "down": jax.random.normal(kd, (F, D), cfg.params_dtype) * s_out,
    }
    if cfg.glu:
        p["gate"] = jax.random.normal(kg, (D, F), cfg.params_dtype) * s_in
    return p


def _act(x, name: str):
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x)


def mlp(p, x, cfg: ModelConfig):
    up = _proj(x, p["up"], "bsd,df->bsf", cfg, ("data", "model"))
    if cfg.glu:
        gate = _proj(x, p["gate"], "bsd,df->bsf", cfg, ("data", "model"))
        h = _act(gate, cfg.act) * up
    else:
        h = _act(up, cfg.act)
    return _proj(h, p["down"], "bsf,fd->bsd", cfg, ("model", "data"))


# ---------------------------------------------------------------------------
# a full pre-norm dense transformer layer
# ---------------------------------------------------------------------------


def init_dense_layer(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.params_dtype),
        "attn": init_attn(ka, cfg),
        "ln2": jnp.zeros((cfg.d_model,), cfg.params_dtype),
        "mlp": init_mlp(km, cfg),
    }


def dense_layer(p, x, cfg: ModelConfig, *, causal=True, cache=None, pos=None):
    h, new_cache = attention(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
        causal=causal, cache=cache, pos=pos,
    )
    x = x + h
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x, new_cache
