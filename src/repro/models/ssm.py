"""Mamba-2 (SSD — state-space duality) block, chunked TPU-friendly form.

Training/prefill uses the blocked SSD algorithm: the sequence is split into
chunks of length Q; within a chunk the output is a masked (decay-weighted)
quadratic contraction (MXU-friendly matmuls), across chunks a cheap linear
recurrence carries the (H, P, N) state.  Decode is the O(1) recurrence.

State layout:
  conv state : (B, K-1, conv_dim)   — last K-1 pre-conv inputs
  ssm state  : (B, H, P, N)         — per-head outer-product state
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm

__all__ = ["SSMState", "init_ssm_block", "ssm_block", "ssm_block_decode", "init_ssm_state"]


class SSMState(NamedTuple):
    conv: jax.Array   # (B, K-1, conv_dim)
    h: jax.Array      # (B, H, P, N)


def _conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssm_block(key, cfg: ModelConfig):
    D = cfg.d_model
    d_inner, H = cfg.d_inner, cfg.ssm_heads
    G, N = cfg.ssm_groups, cfg.ssm_state
    d_in_proj = 2 * d_inner + 2 * G * N + H
    cd = _conv_dim(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm": jnp.zeros((D,), cfg.params_dtype),
        "in_proj": jax.random.normal(k1, (D, d_in_proj), cfg.params_dtype) * D ** -0.5,
        "conv_w": jax.random.normal(k2, (cfg.ssm_conv, cd), cfg.params_dtype) * 0.2,
        "conv_b": jnp.zeros((cd,), cfg.params_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(cfg.params_dtype),
        "D_skip": jnp.ones((H,), cfg.params_dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(cfg.params_dtype),
        "gated_norm": jnp.zeros((d_inner,), cfg.params_dtype),
        "out_proj": jax.random.normal(k4, (d_inner, D), cfg.params_dtype) * d_inner ** -0.5,
    }


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, _conv_dim(cfg)), dtype),
        h=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
    )


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_inner, H = cfg.d_inner, cfg.ssm_heads
    G, N = cfg.ssm_groups, cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : 2 * d_inner + 2 * G * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * G * N :]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv over time.  xBC: (B,S,Cd); w: (K,Cd)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(K):  # K is tiny (4) — unrolled taps stay fused
        out = out + pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


def _ssd_chunked(x, dt, A, Bm, Cm, cfg: ModelConfig, h0=None):
    """Blocked SSD scan.

    x: (B,S,H,P); dt: (B,S,H); A: (H,) negative; Bm/Cm: (B,S,G,N).
    Returns y: (B,S,H,P), final state (B,H,P,N).
    """
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.ssm_chunk, S)
    nc = S // Q
    rep = H // G

    xq = x.reshape(B_, nc, Q, H, P)
    dtq = dt.reshape(B_, nc, Q, H)
    Bq = Bm.reshape(B_, nc, Q, G, N)
    Cq = Cm.reshape(B_, nc, Q, G, N)

    la = jnp.cumsum(dtq * A[None, None, None, :], axis=2)      # (B,nc,Q,H) log-decay
    u = xq * dtq[..., None]                                    # discretized input

    # ---- intra-chunk (quadratic, masked decay) ---------------------------
    # the Q×Q tensors are the memory hot spot: keep them in the compute
    # dtype (bf16); the log-decay math itself stays in f32.
    Bh = jnp.repeat(Bq, rep, axis=3)                           # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cq, rep, axis=3)
    cb = jnp.einsum("bnqhs,bnkhs->bnhqk", Ch, Bh)              # (B,nc,H,Q,Q)
    decay = jnp.exp(
        la[..., :, None, :] - la[..., None, :, :]
    ).astype(x.dtype)                                          # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    att = cb * jnp.transpose(decay, (0, 1, 4, 2, 3))           # (B,nc,H,Q,Q)
    att = jnp.where(mask[None, None, None], att, jnp.zeros((), att.dtype))
    y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", att.astype(x.dtype), u.astype(x.dtype))

    # ---- chunk summary states + inter-chunk recurrence --------------------
    seg = jnp.exp(la[:, :, -1:, :] - la)                       # decay to chunk end
    chunk_state = jnp.einsum(
        "bnqhs,bnqhp->bnhps", (Bh * seg[..., None]).astype(jnp.float32),
        u.astype(jnp.float32),
    )                                                          # (B,nc,H,P,N)
    chunk_decay = jnp.exp(la[:, :, -1, :])                     # (B,nc,H)

    h_init = (
        jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def scan_fn(h, xs):
        cs, cd = xs                                            # (B,H,P,N), (B,H)
        h_out = h                                              # state entering chunk
        h_next = h * cd[..., None, None] + cs
        return h_next, h_out

    cs_t = jnp.moveaxis(chunk_state, 1, 0)                     # (nc,B,H,P,N)
    cd_t = jnp.moveaxis(chunk_decay, 1, 0)                     # (nc,B,H)
    h_final, h_enter = jax.lax.scan(scan_fn, h_init, (cs_t, cd_t))
    h_enter = jnp.moveaxis(h_enter, 0, 1)                      # (B,nc,H,P,N)

    # ---- inter-chunk contribution -----------------------------------------
    indecay = jnp.exp(la)                                      # decay from chunk start
    y_inter = jnp.einsum(
        "bnqhs,bnhps->bnqhp", (Ch * indecay[..., None]).astype(jnp.float32), h_enter
    ).astype(x.dtype)

    y = (y_intra.astype(jnp.float32) + y_inter.astype(jnp.float32))
    return y.reshape(B_, S, H, P).astype(x.dtype), h_final


def ssm_block(p, x, cfg: ModelConfig, state: Optional[SSMState] = None):
    """Full Mamba-2 block (pre-norm, residual outside).  x: (B,S,D).

    Returns (y, final_state)."""
    B_, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    conv_in = xBC
    xBC = _causal_conv(xBC, p["conv_w"].astype(xBC.dtype), p["conv_b"].astype(xBC.dtype))

    d_inner, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    xs = xBC[..., :d_inner].reshape(B_, S, H, P)
    Bm = xBC[..., d_inner : d_inner + G * N].reshape(B_, S, G, N)
    Cm = xBC[..., d_inner + G * N :].reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    h0 = None if state is None else state.h
    if cfg.ssm_impl.startswith("pallas") and h0 is None and \
            S % min(cfg.ssm_chunk, S) == 0:
        # Pallas SSD kernel (TPU target; interpret mode on CPU).  Final
        # state isn't returned by the kernel — training path only.
        from ..kernels.ssd_scan.ops import ssd_scan
        y = ssd_scan(
            xs, dt, A, Bm, Cm, use_pallas=True,
            interpret=(cfg.ssm_impl == "pallas_interpret"),
            block_q=min(cfg.ssm_chunk, S),
        ).astype(x.dtype)
        h_final = None
    else:
        y, h_final = _ssd_chunked(xs, dt, A, Bm, Cm, cfg, h0=h0)
    y = y + xs * p["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gated_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(y.dtype))

    new_state = None
    if state is not None:
        K = cfg.ssm_conv
        conv_tail = conv_in[:, -(K - 1):, :] if S >= K - 1 else jnp.concatenate(
            [state.conv[:, S:, :], conv_in], axis=1
        )
        new_state = SSMState(conv=conv_tail.astype(state.conv.dtype), h=h_final)
    return out, new_state


def ssm_block_decode(p, x, cfg: ModelConfig, state: SSMState):
    """Single-token decode.  x: (B,1,D) -> (B,1,D), updated state."""
    B_, S, D = x.shape
    assert S == 1
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    z, xBC, dt = _split_proj(zxbcdt, cfg)

    # conv over (cached K-1 inputs ++ current)
    K = cfg.ssm_conv
    window = jnp.concatenate([state.conv, xBC.astype(state.conv.dtype)], axis=1)  # (B,K,Cd)
    w = p["conv_w"].astype(window.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(window.dtype)
    xBC_t = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)   # (B,1,Cd)
    new_conv = window[:, 1:, :]

    d_inner, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    xs = xBC_t[..., :d_inner].reshape(B_, H, P)
    Bm = xBC_t[..., d_inner : d_inner + G * N].reshape(B_, G, N)
    Cm = xBC_t[..., d_inner + G * N :].reshape(B_, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                            # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt1 = jax.nn.softplus(
        dt[:, 0, :].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                           # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt1 * A[None, :])                               # (B,H)

    u = xs.astype(jnp.float32) * dt1[..., None]                 # (B,H,P)
    h_new = state.h * a[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", u, Bh.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch.astype(jnp.float32)).astype(x.dtype)
    y = y + xs * p["D_skip"].astype(y.dtype)[None, :, None]
    y = y.reshape(B_, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gated_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(y.dtype))
    return out, SSMState(conv=new_conv, h=h_new)
