"""Unified decoder language model covering the dense / moe / ssm / hybrid /
vlm families.  One init + three entry points (forward / prefill / decode),
all built on ``lax.scan`` over stacked per-layer parameters (compile time is
depth-independent) with optional remat.

Caches:
  dense/moe : KVCache stacked (L, B, S_max, K, hd)
  ssm       : SSMState stacked (L, ...)
  hybrid    : ssm states (L, ...) + shared-attention KVCache stacked over
              invocations (L/k, B, S_max, K, hd)
  vlm       : dense cache; prompt = [patch_embeds ; text]
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    KVCache, attention, dense_layer, init_attn, init_dense_layer, init_mlp,
    mlp, rms_norm,
)
from .moe import init_moe, moe_block
from .ssm import SSMState, init_ssm_block, init_ssm_state, ssm_block, ssm_block_decode

__all__ = ["init_params", "forward", "prefill", "decode", "init_cache", "unembed"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(layer_init, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(layer_init)(keys)


def init_params(key, cfg: ModelConfig):
    ke, kl, kh, ks = jax.random.split(key, 4)
    D, V = cfg.d_model, cfg.vocab_size
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ke, (V, D), cfg.params_dtype) * D ** -0.5,
        "final_norm": jnp.zeros((D,), cfg.params_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kh, (D, V), cfg.params_dtype) * D ** -0.5

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _stack_init(
            lambda k: init_dense_layer(k, cfg), kl, cfg.n_layers
        )
    elif fam == "moe":
        def moe_layer_init(k):
            ka, km = jax.random.split(k)
            return {
                "ln1": jnp.zeros((D,), cfg.params_dtype),
                "attn": init_attn(ka, cfg),
                "ln2": jnp.zeros((D,), cfg.params_dtype),
                "moe": init_moe(km, cfg),
            }
        params["layers"] = _stack_init(moe_layer_init, kl, cfg.n_layers)
    elif fam == "ssm":
        params["layers"] = _stack_init(lambda k: init_ssm_block(k, cfg), kl, cfg.n_layers)
    elif fam == "hybrid":
        params["layers"] = _stack_init(lambda k: init_ssm_block(k, cfg), kl, cfg.n_layers)
        params["shared_attn"] = init_dense_layer(ks, cfg)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


def unembed(params, h, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype)).astype(cfg.logit_dtype)


def _embed(params, tokens, cfg: ModelConfig):
    return jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)


def _hybrid_period(cfg: ModelConfig) -> int:
    return cfg.shared_attn_every or cfg.n_layers


# ---------------------------------------------------------------------------
# forward (train / prefill share the full-sequence path)
# ---------------------------------------------------------------------------


def _dense_stack(params, x, cfg: ModelConfig, caches=None, pos=None,
                 is_moe=False, collect_kv=False):
    """Scan over stacked dense/moe layers; optionally updating KV caches.

    With act_shard_spec set (big-model launch path), the residual stream is
    sequence-sharded over the model axis; each sublayer gathers it ONCE in
    bf16 (Megatron-SP style — recomputed under remat, never saved) and the
    sublayer output reduce-scatters back at the residual add."""
    from jax.sharding import PartitionSpec as _P

    def body(x, xs):
        lp, cache = xs
        if cfg.act_shard_spec:
            x = jax.lax.with_sharding_constraint(x, _P(*cfg.act_shard_spec))
        h, new_cache = attention(
            lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            causal=True, cache=cache, pos=pos, collect_kv=collect_kv,
        )
        x = x + h
        hin = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if is_moe:
            x = x + moe_block(lp["moe"], hin, cfg)
        else:
            x = x + mlp(lp["mlp"], hin, cfg)
        return x, new_cache

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    return x, new_caches


def _ssm_stack(params, x, cfg: ModelConfig, states=None):
    from jax.sharding import PartitionSpec as _P

    def body(x, xs):
        lp, st = xs
        if cfg.act_shard_spec:
            x = jax.lax.with_sharding_constraint(x, _P(*cfg.act_shard_spec))
        h, new_st = ssm_block(lp, x, cfg, state=st)
        return x + h, new_st

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    return x, new_states


def _hybrid_stack(params, x, cfg: ModelConfig, states=None, kv_caches=None,
                  pos=None, collect_kv=False):
    """Groups of ``shared_attn_every`` ssm blocks + one shared attn layer."""
    k = _hybrid_period(cfg)
    G = cfg.n_layers // k
    grouped = jax.tree.map(
        lambda a: a.reshape((G, k) + a.shape[1:]), params["layers"]
    )
    grouped_states = (
        None if states is None
        else jax.tree.map(lambda a: a.reshape((G, k) + a.shape[1:]), states)
    )
    shared = params["shared_attn"]

    from jax.sharding import PartitionSpec as _P

    def group_body(x, xs):
        gp, gst, gkv = xs

        def inner(x, ys):
            lp, st = ys
            if cfg.act_shard_spec:
                x = jax.lax.with_sharding_constraint(x, _P(*cfg.act_shard_spec))
            h, new_st = ssm_block(lp, x, cfg, state=st)
            return x + h, new_st

        x, new_gst = jax.lax.scan(inner, x, (gp, gst))
        if cfg.act_shard_spec:
            x = jax.lax.with_sharding_constraint(x, _P(*cfg.act_shard_spec))
        h, new_kv = attention(
            shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps), cfg,
            causal=True, cache=gkv, pos=pos, collect_kv=collect_kv,
        )
        x = x + h
        x = x + mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
        return x, (new_gst, new_kv)

    if cfg.remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, (new_states, new_kvs) = jax.lax.scan(
        group_body, x, (grouped, grouped_states, kv_caches)
    )
    if new_states is not None:
        new_states = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_states
        )
    return x, (new_states, new_kvs)


def forward(
    params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> jax.Array:
    """Full-sequence forward -> logits (B, S_out, V)."""
    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)   # (B, n_img, D)
        x = jnp.concatenate([pe, x], axis=1)

    if cfg.family in ("dense", "vlm"):
        x, _ = _dense_stack(params, x, cfg)
    elif cfg.family == "moe":
        x, _ = _dense_stack(params, x, cfg, is_moe=True)
    elif cfg.family == "ssm":
        x, _ = _ssm_stack(params, x, cfg)
    elif cfg.family == "hybrid":
        x, _ = _hybrid_stack(params, x, cfg)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm":
        x = x[:, batch["patch_embeds"].shape[1]:, :]   # only text positions
    return unembed(params, x, cfg)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "vlm", "moe"):
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if cfg.family == "ssm":
        st = init_ssm_state(cfg, batch)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st
        )
    if cfg.family == "hybrid":
        st = init_ssm_state(cfg, batch)
        states = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st
        )
        G = cfg.n_layers // _hybrid_period(cfg)
        shape = (G, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return (states, KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg: ModelConfig, max_len: Optional[int] = None):
    """Process the prompt, fill caches.  Returns (last-token logits, cache).

    KV caches are the scan-collected (post-rope) K/V of the prompt itself —
    no zero-init max_len buffers or update-slice copies.  If ``max_len`` >
    prompt length, the cache is padded once at the end (decode continues by
    writing at pos = prompt_len)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    n_img = batch["patch_embeds"].shape[1] if cfg.family == "vlm" else 0
    total = S + n_img

    x = _embed(params, tokens, cfg)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)

    if cfg.family in ("dense", "vlm", "moe"):
        x, cache = _dense_stack(
            params, x, cfg, collect_kv=True, is_moe=(cfg.family == "moe")
        )
    elif cfg.family == "ssm":
        states = init_cache(cfg, B, total)
        x, cache = _ssm_stack(params, x, cfg, states=states)
    elif cfg.family == "hybrid":
        states, _ = init_cache(cfg, B, total)
        x, cache = _hybrid_stack(
            params, x, cfg, states=states, kv_caches=None, collect_kv=True
        )
        states_out, kvs = cache
        cache = (states_out, kvs)

    if max_len is not None and max_len > total and cfg.family != "ssm":
        def pad(kv):
            return KVCache(
                jnp.pad(kv.k, ((0, 0),) * 2 + ((0, max_len - total),) + ((0, 0),) * 2),
                jnp.pad(kv.v, ((0, 0),) * 2 + ((0, max_len - total),) + ((0, 0),) * 2),
            )
        if cfg.family == "hybrid":
            cache = (cache[0], pad(cache[1]))
        else:
            cache = pad(cache)

    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), cache


def _dense_decode_stack(params, x, cfg: ModelConfig, caches, pos, is_moe=False):
    """Decode path: the stacked KV cache is threaded as a scan CARRY with
    per-layer dynamic index updates — XLA aliases the buffer in place
    (xs->ys cache threading doubles the cache in HBM)."""

    def body(carry, xs):
        x, kc, vc = carry
        lp, i = xs
        cache_l = KVCache(
            jax.lax.dynamic_index_in_dim(kc, i, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(vc, i, 0, keepdims=False),
        )
        h, new_cache = attention(
            lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            causal=True, cache=cache_l, pos=pos,
        )
        x = x + h
        hin = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if is_moe:
            x = x + moe_block(lp["moe"], hin, cfg)
        else:
            x = x + mlp(lp["mlp"], hin, cfg)
        kc = jax.lax.dynamic_update_index_in_dim(kc, new_cache.k, i, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, new_cache.v, i, 0)
        return (x, kc, vc), None

    (x, kc, vc), _ = jax.lax.scan(
        body, (x, caches.k, caches.v),
        (params["layers"], jnp.arange(cfg.n_layers)),
    )
    return x, KVCache(kc, vc)


def decode(params, cache, token, pos, cfg: ModelConfig):
    """One decode step.  token: (B,1); pos: scalar int32 (write offset).

    Returns (logits (B,1,V), new cache)."""
    x = _embed(params, token, cfg)
    if cfg.family in ("dense", "vlm", "moe"):
        x, cache = _dense_decode_stack(
            params, x, cfg, caches=cache, pos=pos,
            is_moe=(cfg.family == "moe"),
        )
    elif cfg.family == "ssm":
        def body(x, xs):
            lp, st = xs
            h, new_st = ssm_block_decode(lp, x, cfg, st)
            return x + h, new_st
        x, cache = jax.lax.scan(body, x, (params["layers"], cache))
    elif cfg.family == "hybrid":
        states, kvs = cache
        k = _hybrid_period(cfg)
        G = cfg.n_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((G, k) + a.shape[1:]), params["layers"]
        )
        gstates = jax.tree.map(lambda a: a.reshape((G, k) + a.shape[1:]), states)
        shared = params["shared_attn"]

        def group_body(x, xs):
            gp, gst, gkv = xs
            def inner(x, ys):
                lp, st = ys
                h, new_st = ssm_block_decode(lp, x, cfg, st)
                return x + h, new_st
            x, new_gst = jax.lax.scan(inner, x, (gp, gst))
            h, new_kv = attention(
                shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps), cfg,
                causal=True, cache=gkv, pos=pos,
            )
            x = x + h
            x = x + mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
            return x, (new_gst, new_kv)

        x, (new_states, new_kvs) = jax.lax.scan(group_body, x, (grouped, gstates, kvs))
        new_states = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_states
        )
        cache = (new_states, new_kvs)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), cache
