"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | encdec | ssm | hybrid | moe | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU / plain)
    glu: bool = True
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # hybrid (zamba2): shared attention block applied every k ssm blocks
    shared_attn_every: int = 0
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # encdec (whisper)
    n_enc_layers: int = 0
    dec_ratio: int = 8              # decoder_len = enc_len // dec_ratio
    max_dec_len: int = 4096
    # vlm
    n_img_tokens: int = 0
    # numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "xla"          # xla | pallas | pallas_interpret
    ssm_impl: str = "xla"           # xla | pallas | pallas_interpret
    logit_dtype: str = "float32"
    # optional residual-activation sharding constraint (tuple form of a
    # PartitionSpec, e.g. (('data',), 'model', None) = sequence-sharded
    # residuals between layers).  () = off.  Set by the launcher per mesh.
    act_shard_spec: tuple = ()
    # pin the MoE dispatch buffers (E, C, D) to expert-parallel sharding over
    # the 'model' axis (set by the launcher when n_experts % model == 0).
    moe_ep_shard: bool = False
    # route the big projections through the custom-VJP matmul that computes
    # weight grads in param dtype directly into their (FSDP x TP) layout
    # (reduce-scatter instead of full-shape f32 all-reduce) — launcher-set.
    grad_shard: bool = False
    mesh_data_size: int = 0        # launcher-set with grad_shard (for
    mesh_model_size: int = 0       # per-dim divisibility checks)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(self.n_kv_heads, 1))

    @property
    def d_inner(self) -> int:       # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode
    # decode: seq_len = existing KV/state context length, 1 new token.


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)
