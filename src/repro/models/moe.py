"""Mixture-of-Experts block with capacity-bounded sort-based dispatch.

TPU-native design (DESIGN.md §6): no ragged ops — tokens are routed top-k,
ranked per expert by router probability, and the top ``capacity`` tokens per
expert are gathered into a dense (E, C, D) buffer.  Expert matmuls are plain
einsums with the expert axis sharded over the ``model`` mesh axis (expert
parallelism); XLA inserts the all-to-all-style collectives from the sharding
annotations.  Compute is ``cf·T·k·D·F`` — no dense-over-all-experts waste.

A pure-jnp one-hot reference (``moe_block_dense``) serves as the oracle for
tests (identical math when nothing is dropped).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _act

__all__ = ["init_moe", "moe_block", "moe_block_dense", "route_topk"]


def init_moe(key, cfg: ModelConfig):
    D, Fe, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s_in, s_out = D ** -0.5, Fe ** -0.5
    p = {
        "router": jax.random.normal(kr, (D, E), jnp.float32) * s_in,
        "e_gate": jax.random.normal(kg, (E, D, Fe), cfg.params_dtype) * s_in,
        "e_up": jax.random.normal(ku, (E, D, Fe), cfg.params_dtype) * s_in,
        "e_down": jax.random.normal(kd, (E, Fe, D), cfg.params_dtype) * s_out,
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * cfg.d_ff
        k1, k2, k3, k4 = jax.random.split(ks, 4)
        p["shared"] = {
            "gate": jax.random.normal(k1, (D, Fs), cfg.params_dtype) * s_in,
            "up": jax.random.normal(k2, (D, Fs), cfg.params_dtype) * s_in,
            "down": jax.random.normal(k3, (Fs, D), cfg.params_dtype) * (Fs ** -0.5),
            "shared_gate": jax.random.normal(k4, (D, 1), jnp.float32) * s_in,
        }
    return p


def route_topk(router_logits: jax.Array, k: int):
    """Top-k routing with renormalized softmax weights.

    router_logits: (T, E) f32.  Returns (expert_idx (T,k), weights (T,k))."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
    return idx, w


def _capacity(T: int, k: int, E: int, cf: float) -> int:
    c = int(max(1, round(cf * T * k / E)))
    # keep the MXU minor dims respectable but never above T
    return min(max(c, 4), T)


MOE_CHUNK_TOKENS = 65536   # dispatch chunk bound (prefill of 1M-token
                           # batches would otherwise materialize ~20 GB
                           # (E, C, D) buffers); routing/capacity are
                           # computed per chunk — standard block-wise MoE.


def moe_block(p, x, cfg: ModelConfig, capacity: Optional[int] = None):
    """x: (B, S, D) -> (B, S, D).  Sort-based capacity dispatch; token-
    chunked (lax.map) above MOE_CHUNK_TOKENS."""
    B, S, D = x.shape
    if B * S > MOE_CHUNK_TOKENS and (B * S) % MOE_CHUNK_TOKENS == 0:
        nc = (B * S) // MOE_CHUNK_TOKENS
        xc = x.reshape(nc, 1, MOE_CHUNK_TOKENS, D)
        yc = jax.lax.map(lambda t: _moe_block_inner(p, t, cfg, capacity), xc)
        return yc.reshape(B, S, D)
    return _moe_block_inner(p, x, cfg, capacity)


def _moe_block_inner(p, x, cfg: ModelConfig, capacity: Optional[int] = None):
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.moe_top_k
    xt = x.reshape(T, D)

    router_logits = xt.astype(jnp.float32) @ p["router"]           # (T, E)
    idx, w = route_topk(router_logits, k)                          # (T,k)

    C = _capacity(T, k, E, cfg.capacity_factor) if capacity is None else capacity

    # ---- rank tokens within each expert by router weight ----------------
    flat_e = idx.reshape(-1)                                       # (T*k,)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    # sort by (expert, -weight): strongest tokens keep their slot.
    # two stable passes => exact ordering without mixed-key precision issues.
    # routing is a discrete decision: no gradient flows through the sort
    # (grad w.r.t. router weights flows through slot_w / softmax instead).
    order1 = jnp.argsort(-jax.lax.stop_gradient(flat_w))
    order = order1[jnp.argsort(flat_e[order1])]
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]
    starts = jnp.searchsorted(se, jnp.arange(E))                   # (E,)
    pos_in_e = jnp.arange(T * k) - starts[se]                      # rank in expert
    keep = pos_in_e < C

    # ---- dense dispatch buffers -----------------------------------------
    # token id per (expert, slot); dropped slots point at a zero row (T).
    # dropped assignments write to column C => out of bounds => mode="drop".
    slot_tok = jnp.full((E, C), T, jnp.int32)
    slot_w = jnp.zeros((E, C), jnp.float32)
    c_safe = jnp.where(keep, pos_in_e, C)
    slot_tok = slot_tok.at[se, c_safe].set(st.astype(jnp.int32), mode="drop")
    slot_w = slot_w.at[se, c_safe].set(sw, mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    xe = jnp.take(xt_pad, slot_tok, axis=0)                        # (E, C, D)

    def _ep(t):  # pin expert-parallel sharding through the dispatch
        if not cfg.moe_ep_shard:
            return t
        from jax.sharding import PartitionSpec as _P
        return jax.lax.with_sharding_constraint(
            t, _P(*(("model",) + (None,) * (t.ndim - 1)))
        )

    # ---- expert computation (E sharded over 'model') ----------------------
    from .pmm import matmul as _pmm
    from .layers import _sanitize_dw_spec

    def _emm(a, w, subs, dw_spec):
        if cfg.grad_shard and cfg.moe_ep_shard:
            meta = (_sanitize_dw_spec(cfg, w, dw_spec),
                    cfg.mesh_data_size, cfg.mesh_model_size, None)
            return _pmm(a, w.astype(a.dtype), subs, meta)
        return jnp.einsum(subs, a, w.astype(a.dtype))

    xe = _ep(xe)
    gate = _emm(xe, p["e_gate"], "ecd,edf->ecf", ("model", "data", None))
    up = _emm(xe, p["e_up"], "ecd,edf->ecf", ("model", "data", None))
    h = _ep(_act(gate, cfg.act) * up)
    ye = _ep(_emm(h, p["e_down"], "ecf,efd->ecd", ("model", None, "data")))

    # ---- combine: scatter-add weighted expert outputs ---------------------
    yw = ye * slot_w[..., None].astype(ye.dtype)
    yt = jnp.zeros((T + 1, D), ye.dtype).at[slot_tok.reshape(-1)].add(
        yw.reshape(-1, D), mode="drop"
    )[:T]

    # ---- shared experts (Qwen2-MoE style, sigmoid-gated) -------------------
    if cfg.n_shared_experts:
        sp = p["shared"]
        g = _act(xt @ sp["gate"].astype(xt.dtype), cfg.act)
        hs = g * (xt @ sp["up"].astype(xt.dtype))
        ys = hs @ sp["down"].astype(xt.dtype)
        sgate = jax.nn.sigmoid(xt.astype(jnp.float32) @ sp["shared_gate"])
        yt = yt + ys * sgate.astype(ys.dtype)

    return yt.reshape(B, S, D)


def moe_block_dense(p, x, cfg: ModelConfig):
    """One-hot dense reference (oracle): same math, no token dropping."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    router_logits = xt.astype(jnp.float32) @ p["router"]
    idx, w = route_topk(router_logits, cfg.moe_top_k)
    comb = jnp.zeros((T, cfg.n_experts), jnp.float32).at[
        jnp.arange(T)[:, None], idx
    ].add(w)                                                       # (T, E)
    gate = jnp.einsum("td,edf->tef", xt, p["e_gate"].astype(xt.dtype))
    up = jnp.einsum("td,edf->tef", xt, p["e_up"].astype(xt.dtype))
    h = _act(gate, cfg.act) * up
    ye = jnp.einsum("tef,efd->ted", h, p["e_down"].astype(xt.dtype))
    yt = jnp.einsum("ted,te->td", ye, comb.astype(ye.dtype))
    if cfg.n_shared_experts:
        sp = p["shared"]
        g = _act(xt @ sp["gate"].astype(xt.dtype), cfg.act)
        hs = g * (xt @ sp["up"].astype(xt.dtype))
        ys = hs @ sp["down"].astype(xt.dtype)
        sgate = jax.nn.sigmoid(xt.astype(jnp.float32) @ sp["shared_gate"])
        yt = yt + ys * sgate.astype(ys.dtype)
    return yt.reshape(B, S, D)
