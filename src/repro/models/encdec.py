"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, D).  The encoder applies
bidirectional attention over frames (+ sinusoidal positions); the decoder is
a causal LM with cross-attention into the encoder states.

Convention for the mechanical shape grid (DESIGN.md §4): for a cell with
sequence length S, encoder length = S and decoder length = S // dec_ratio
(train / prefill).  Decode = 1 new decoder token attending to a cached
decoder prefix and S cached encoder states.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    KVCache, attention, init_attn, init_mlp, mlp, rms_norm, sinusoidal_pos,
)

__all__ = ["init_params", "forward", "prefill", "decode", "EncDecCache"]


class EncDecCache(NamedTuple):
    self_kv: KVCache     # (L, B, S_dec_max, K, hd)
    cross_kv: KVCache    # (L, B, S_enc, K, hd) — precomputed at prefill


def _init_enc_layer(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.params_dtype),
        "attn": init_attn(ka, cfg),
        "ln2": jnp.zeros((cfg.d_model,), cfg.params_dtype),
        "mlp": init_mlp(km, cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.params_dtype),
        "self_attn": init_attn(ka, cfg),
        "ln_x": jnp.zeros((cfg.d_model,), cfg.params_dtype),
        "cross_attn": init_attn(kc, cfg),
        "ln2": jnp.zeros((cfg.d_model,), cfg.params_dtype),
        "mlp": init_mlp(km, cfg),
    }


def init_params(key, cfg: ModelConfig):
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    D, V = cfg.d_model, cfg.vocab_size
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": jax.random.normal(ke, (V, D), cfg.params_dtype) * D ** -0.5,
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": jnp.zeros((D,), cfg.params_dtype),
        "final_norm": jnp.zeros((D,), cfg.params_dtype),
        "lm_head": jax.random.normal(kh, (D, V), cfg.params_dtype) * D ** -0.5,
    }


def _encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    from jax.sharding import PartitionSpec as _P
    B, S, D = frames.shape
    x = frames.astype(cfg.compute_dtype)
    x = x + sinusoidal_pos(S, D, x.dtype)[None]

    def body(x, lp):
        if cfg.act_shard_spec:
            x = jax.lax.with_sharding_constraint(x, _P(*cfg.act_shard_spec))
        h, _ = attention(
            lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            causal=False, use_rope=False,
        )
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(params, enc, cfg: ModelConfig):
    """Precompute per-layer cross-attention K/V from encoder states."""
    def body(_, lp):
        k = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["k"].astype(enc.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["v"].astype(enc.dtype))
        return None, KVCache(k, v)
    _, kv = jax.lax.scan(body, None, params["dec_layers"])
    return kv


def _dec_stack(params, x, cfg, enc=None, cross=None, self_caches=None, pos=None,
               collect_kv=False):
    """Decoder stack; either fresh encoder states (train) or cached cross K/V."""

    from jax.sharding import PartitionSpec as _P

    def body(x, xs):
        lp, cross_l, self_c = xs
        if cfg.act_shard_spec:
            x = jax.lax.with_sharding_constraint(x, _P(*cfg.act_shard_spec))
        h, new_self = attention(
            lp["self_attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            causal=True, cache=self_c, pos=pos, use_rope=True,
            collect_kv=collect_kv,
        )
        x = x + h
        if cross_l is not None:
            h, _ = attention(
                lp["cross_attn"], rms_norm(x, lp["ln_x"], cfg.norm_eps), cfg,
                causal=False, precomputed_kv=cross_l,
            )
        else:
            h, _ = attention(
                lp["cross_attn"], rms_norm(x, lp["ln_x"], cfg.norm_eps), cfg,
                causal=False, kv_x=enc, use_rope=False,
            )
        x = x + h
        x = x + mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x, new_self

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    return jax.lax.scan(body, x, (params["dec_layers"], cross, self_caches))


def forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Training forward: frames + decoder tokens -> decoder logits."""
    enc = _encode(params, batch["frames"], cfg)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
    x, _ = _dec_stack(params, x, cfg, enc=enc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(x.dtype)
    ).astype(cfg.logit_dtype)


def prefill(params, batch, cfg: ModelConfig, max_dec_len: Optional[int] = None):
    """Encode frames, prefill the decoder prompt.  Returns (logits, cache)."""
    enc = _encode(params, batch["frames"], cfg)
    cross = _cross_kv(params, enc, cfg)
    tokens = batch["tokens"]
    B, S_dec = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x, self_kv = _dec_stack(params, x, cfg, cross=cross, collect_kv=True)
    max_dec_len = max_dec_len or cfg.max_dec_len
    if max_dec_len > S_dec:
        pad = ((0, 0), (0, 0), (0, max_dec_len - S_dec), (0, 0), (0, 0))
        self_kv = KVCache(jnp.pad(self_kv.k, pad), jnp.pad(self_kv.v, pad))
    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(x.dtype)
    ).astype(cfg.logit_dtype)
    return logits, EncDecCache(self_kv, cross)


def decode(params, cache: EncDecCache, token, pos, cfg: ModelConfig):
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.compute_dtype)
    x, self_kv = _dec_stack(
        params, x, cfg, cross=cache.cross_kv, self_caches=cache.self_kv, pos=pos
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(x.dtype)
    ).astype(cfg.logit_dtype)
    return logits, EncDecCache(self_kv, cache.cross_kv)
