"""Optimizers (AdamW, Adafactor) and LR schedules — self-contained, pytree
native.  Adafactor's factored second moment keeps optimizer state ~O(rows +
cols) for matrices, which is what lets the 405B/1T archs fit HBM (DESIGN §6).

State layout: per-leaf state lists aligned with ``jax.tree.leaves(params)``
(lists are pytrees, so states shard/checkpoint like any other tree).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "warmup_cosine", "make_optimizer"]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    # (grads, state, params, step) -> (new_params, new_state)
    update: Callable[[Any, Any, Any, jax.Array], tuple]


def warmup_cosine(peak_lr: float, warmup: int = 200, total: int = 10000, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * (step + 1) / warmup
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _chain_barrier(prev, *xs):
    """Serialize per-leaf optimizer updates: leaf i's inputs are barriered
    against leaf i-1's output, so XLA can't inflate peak memory by running
    every leaf's f32 temporaries concurrently."""
    if prev is None:
        return xs
    out = jax.lax.optimization_barrier(tuple(xs) + (prev,))
    return out[:-1]


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0):
    def init(params):
        leaves = jax.tree.leaves(params)
        return {
            "m": [jnp.zeros(p.shape, jnp.float32) for p in leaves],
            "v": [jnp.zeros(p.shape, jnp.float32) for p in leaves],
        }

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        p_leaves, treedef = jax.tree.flatten(params)
        g_leaves = jax.tree.leaves(grads)
        new_p, new_m, new_v = [], [], []
        prev = None
        for p, g, m, v in zip(p_leaves, g_leaves, state["m"], state["v"]):
            g, = _chain_barrier(prev, g)
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_m.append(m)
            new_v.append(v)
            prev = new_p[-1]
        return jax.tree.unflatten(treedef, new_p), {"m": new_m, "v": new_v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment over the trailing two dims)
# ---------------------------------------------------------------------------


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor(
    lr_fn,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    beta1: Optional[float] = None,   # None => no first moment (memory-lean)
    weight_decay: float = 0.0,
    # optionally lax.map the update over dim 0 of huge stacked leaves
    # (bounds f32 temps to one layer; measured neutral-to-negative on the
    # CPU cost model, so off by default — kept for real-TPU experiments)
    scan_update_threshold: Optional[int] = None,
):
    def init(params):
        leaves = jax.tree.leaves(params)
        v = []
        for p in leaves:
            if _factored(p):
                v.append({
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                })
            else:
                v.append({"v": jnp.zeros(p.shape, jnp.float32)})
        st = {"v": v}
        if beta1 is not None:
            st["m"] = [jnp.zeros(p.shape, jnp.float32) for p in leaves]
        return st

    def _leaf_update(p, g, vs, m, lr):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p):
            vr = decay * vs["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * vs["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            vhat = (vr / denom)[..., None] * vc[..., None, :]
            new_vs = {"vr": vr, "vc": vc}
        else:
            vhat = decay * vs["v"] + (1 - decay) * g2
            new_vs = {"v": vhat}
        u = g32 * jax.lax.rsqrt(vhat + eps)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        new_m = None
        if beta1 is not None:
            new_m = beta1 * m + (1 - beta1) * u
            u = new_m
        if weight_decay and p.ndim >= 2:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_vs, new_m

    def update(grads, state, params, step):
        lr = lr_fn(step)
        p_leaves, treedef = jax.tree.flatten(params)
        g_leaves = jax.tree.leaves(grads)
        m_list = state.get("m", [None] * len(p_leaves))
        new_p, new_v, new_m = [], [], []
        prev = None
        for p, g, vs, m in zip(p_leaves, g_leaves, state["v"], m_list):
            g, = _chain_barrier(prev, g)
            if scan_update_threshold is not None and p.ndim >= 3 \
                    and p.shape[0] > 1 and p.size > scan_update_threshold \
                    and beta1 is None:
                # stacked-layer leaf: scan the update over dim 0 so the f32
                # temporaries are one layer's worth, not the whole stack's
                npv, nvs = jax.lax.map(
                    lambda xs: _leaf_update(xs[0], xs[1], xs[2], None, lr)[:2],
                    (p, g, vs),
                )
                new_p.append(npv)
                new_v.append(nvs)
            else:
                npv, nvs, nm = _leaf_update(p, g, vs, m, lr)
                new_p.append(npv)
                new_v.append(nvs)
                if beta1 is not None:
                    new_m.append(nm)
            prev = new_p[-1]
        new_state = {"v": new_v}
        if beta1 is not None:
            new_state["m"] = new_m
        return jax.tree.unflatten(treedef, new_p), new_state

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)
