"""Train / serve step builders.

``make_train_step`` produces a jit-able ``(state, batch) -> (state, metrics)``
with microbatched gradient accumulation (``lax.scan``) — live activation
memory scales with the microbatch, which is what makes the 405B/1T train
cells fit (DESIGN.md §6).  Loss is masked token cross-entropy in f32 with
optional z-loss.  Gradient accumulation dtype follows the parameter dtype.

``make_serve_step`` wraps prefill/decode for the serving shapes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import encdec, lm
from ..models.config import ModelConfig
from .optimizer import Optimizer

__all__ = ["TrainState", "make_train_step", "make_serve_step", "init_train_state", "xent_loss"]


class TrainState(NamedTuple):
    step: jax.Array          # scalar int32
    params: Any
    opt_state: Any


def xent_loss(logits, labels, z_loss: float = 1e-4):
    """Masked softmax cross-entropy (f32).  labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    if z_loss:
        loss = loss + z_loss * ((lse * mask) ** 2).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss


def _model_forward(cfg: ModelConfig):
    return encdec.forward if cfg.family == "encdec" else lm.forward


def init_train_state(key, cfg: ModelConfig, optimizer: Optimizer) -> TrainState:
    init_fn = encdec.init_params if cfg.family == "encdec" else lm.init_params
    params = init_fn(key, cfg)
    return TrainState(jnp.zeros((), jnp.int32), params, optimizer.init(params))


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    accum_steps: int = 1,
    label_key: str = "labels",
    batch_axes: Optional[tuple] = None,   # mesh axes sharding the batch dim
):
    forward = _model_forward(cfg)

    def loss_fn(params, mb):
        logits = forward(params, mb, cfg)
        return xent_loss(logits, mb[label_key])

    grad_fn = jax.value_and_grad(loss_fn)

    def _pin_batch(mb):
        """The (accum, mb, ...) reshape can defeat GSPMD's batch-sharding
        propagation (observed: accum < axis size => microbatch replicated).
        Re-pin each microbatch leaf's leading dim explicitly.

        ``batch_axes``: tuple of (mesh_axis_name, size) pairs; the longest
        prefix whose product divides the microbatch size is used."""
        if not batch_axes:
            return mb
        from jax.sharding import PartitionSpec as P

        def pin_leaf(a):
            names = []
            prod = 1
            for name, size in batch_axes:
                if a.shape[0] % (prod * size) == 0:
                    names.append(name)
                    prod *= size
                else:
                    break
            if not names:
                return a
            entry = names[0] if len(names) == 1 else tuple(names)
            return jax.lax.with_sharding_constraint(
                a, P(entry, *([None] * (a.ndim - 1)))
            )
        return jax.tree.map(pin_leaf, mb)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params

        if accum_steps == 1:
            loss, grads = grad_fn(params, _pin_batch(batch))
        else:
            # (GB, ...) -> (accum, mb, ...)
            mb_batch = jax.tree.map(
                lambda a: a.reshape((accum_steps, a.shape[0] // accum_steps) + a.shape[1:]),
                batch,
            )

            def mb_step(acc, mb):
                gsum, lsum = acc
                mb = _pin_batch(mb)
                l, g = grad_fn(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), params
            )
            (gsum, lsum), _ = jax.lax.scan(mb_step, (g0, jnp.float32(0)), mb_batch)
            scale = 1.0 / accum_steps
            grads = jax.tree.map(lambda g: g * jnp.asarray(scale, g.dtype), gsum)
            loss = lsum * scale

        new_params, new_opt = optimizer.update(grads, state.opt_state, params, state.step)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "step": state.step}
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return train_step


def make_serve_step(cfg: ModelConfig, kind: str, max_len: Optional[int] = None):
    """kind = 'prefill' | 'decode'.

    prefill: (params, batch) -> (logits, cache)
    decode : (params, cache, token, pos) -> (logits, cache)
    """
    mod = encdec if cfg.family == "encdec" else lm

    if kind == "prefill":
        if cfg.family == "encdec":
            def prefill_step(params, batch):
                return encdec.prefill(params, batch, cfg)
        else:
            def prefill_step(params, batch):
                return lm.prefill(params, batch, cfg, max_len=max_len)
        return prefill_step

    if kind == "decode":
        def decode_step(params, cache, token, pos):
            return mod.decode(params, cache, token, pos, cfg)
        return decode_step

    raise ValueError(kind)
