"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155.  [hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, head_dim=64, d_ff=8192,
    vocab_size=49155, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, tie_embeddings=True,
)

ARCH = ArchDef(
    arch_id="granite-3-2b", config=CONFIG, smoke=SMOKE,
    # vocab 49155 is not 16-divisible => logits replicate over model; the
    # deeper accumulation keeps per-microbatch logits ~1.6 GB/dev.
    optimizer="adamw", grad_accum=8, skip_shapes=FULL_ATTN_SKIP,
)
