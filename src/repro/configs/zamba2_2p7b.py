"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; hf]

Sub-quadratic backbone => long_500k runs (DESIGN.md §4).
"""
from ..models.config import ModelConfig
from .base import ArchDef

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240,
    vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    shared_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    shared_attn_every=2,
)

ARCH = ArchDef(
    arch_id="zamba2-2.7b", config=CONFIG, smoke=SMOKE,
    optimizer="adamw", grad_accum=8,
)
