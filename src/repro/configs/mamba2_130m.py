"""mamba2-130m [ssm]: 24L d_model=768 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Attention-free => long_500k runs (state is O(1) per token).
"""
from ..models.config import ModelConfig
from .base import ArchDef

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768,
    vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64,
    vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    tie_embeddings=True,
)

ARCH = ArchDef(
    arch_id="mamba2-130m", config=CONFIG, smoke=SMOKE,
    optimizer="adamw", grad_accum=1,
    # 24 ssm heads don't divide the 16-wide model axis — the model axis joins
    # the batch axes instead (pure DP; 130M params replicate comfortably).
    dp_over_model=True,
)
