"""whisper-base [audio]: 6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865.

Enc-dec; conv audio frontend stubbed (input_specs supplies frame embeddings).
[arXiv:2212.04356; unverified]
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048,
    vocab_size=51865, act="gelu", glu=False,
    dec_ratio=8, max_dec_len=4096,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512, act="gelu", glu=False,
    dec_ratio=8, max_dec_len=64,
)

ARCH = ArchDef(
    arch_id="whisper-base", config=CONFIG, smoke=SMOKE,
    optimizer="adamw", grad_accum=1, skip_shapes=FULL_ATTN_SKIP,
    # 8 heads / d_model 512 don't use a 16-wide TP axis; the 72M-param model
    # replicates trivially => pure DP over all mesh axes.
    dp_over_model=True,
)
