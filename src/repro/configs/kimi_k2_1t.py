"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 routed top-8 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

1T params: bf16 params + Adafactor + EP (384 % 16 == 0) over the model
axis.  head_dim = 7168/64 = 112 per the assigned spec (the real model uses
MLA; noted in DESIGN.md §9).
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, head_dim=112, d_ff=2048,
    vocab_size=163840, rope_theta=5e4,
    n_experts=384, n_shared_experts=0, moe_top_k=8, capacity_factor=1.25,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe",
    n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32,
    vocab_size=512,
    n_experts=8, n_shared_experts=0, moe_top_k=4, capacity_factor=1.25,
)

ARCH = ArchDef(
    arch_id="kimi-k2-1t-a32b", config=CONFIG, smoke=SMOKE,
    optimizer="adafactor", grad_accum=16, skip_shapes=FULL_ATTN_SKIP,
)
