"""Architecture registry scaffolding: ArchDef, input specs, smoke batches.

Every assigned architecture module defines ``ARCH = ArchDef(...)`` with the
exact published config and a reduced smoke config of the same family.
``input_specs`` produces ShapeDtypeStruct stand-ins (no allocation) for
every (arch × shape) cell; ``smoke_batch`` produces small concrete arrays
for the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import encdec, lm
from ..models.config import ModelConfig, ShapeSpec, SHAPES

__all__ = ["ArchDef", "input_specs", "smoke_batch", "decode_operand_specs"]


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    grad_accum: int = 1                      # microbatch accumulation, train_4k
    skip_shapes: Tuple[Tuple[str, str], ...] = ()   # (shape_name, reason)
    # pure data-parallel over ALL mesh axes (for archs whose inner dims don't
    # divide the model axis — e.g. mamba2-130m with 24 ssm heads):
    dp_over_model: bool = False

    def skip_reason(self, shape_name: str) -> Optional[str]:
        for name, reason in self.skip_shapes:
            if name == shape_name:
                return reason
        return None


FULL_ATTN_SKIP = (
    ("long_500k", "skipped (full-attention arch; 524288-token dense prefill/"
                  "decode cache is outside the published model family — DESIGN.md §4)"),
)


def _token_struct(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input ShapeDtypeStructs for train/prefill batches."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        S_dec = max(8, S // cfg.dec_ratio)
        specs = {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": _token_struct((B, S_dec)),
        }
        if shape.kind == "train":
            specs["labels"] = _token_struct((B, S_dec))
        return specs
    if cfg.family == "vlm":
        n_img = cfg.n_img_tokens
        specs = {
            "tokens": _token_struct((B, S - n_img)),
            "patch_embeds": jax.ShapeDtypeStruct((B, n_img, cfg.d_model), jnp.bfloat16),
        }
        if shape.kind == "train":
            specs["labels"] = _token_struct((B, S - n_img))
        return specs
    specs = {"tokens": _token_struct((B, S))}
    if shape.kind == "train":
        specs["labels"] = _token_struct((B, S))
    return specs


def decode_operand_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(cache, token, pos) ShapeDtypeStructs for a decode cell.

    The cache holds ``seq_len`` positions; the new token writes at
    pos = seq_len - 1 and attends over the full window."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        S_dec = max(8, S // cfg.dec_ratio)
        cache = jax.eval_shape(
            lambda: encdec.EncDecCache(
                self_kv=encdec.KVCache(
                    jnp.zeros((cfg.n_layers, B, S_dec, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                    jnp.zeros((cfg.n_layers, B, S_dec, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                ),
                cross_kv=encdec.KVCache(
                    jnp.zeros((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                    jnp.zeros((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                ),
            )
        )
        pos_ref = S_dec - 1
    else:
        cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, S))
        pos_ref = S - 1
    token = _token_struct((B, 1))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, pos, pos_ref


def smoke_batch(cfg: ModelConfig, *, batch: int = 2, seq: int = 32, seed: int = 0):
    """Small concrete batch matching ``input_specs`` layout (train kind)."""
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    if cfg.family == "encdec":
        S_dec = max(8, seq // cfg.dec_ratio)
        return {
            "frames": jnp.asarray(
                rng.normal(0, 1, (batch, seq, cfg.d_model)), jnp.bfloat16
            ),
            "tokens": jnp.asarray(rng.integers(0, V, (batch, S_dec)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, V, (batch, S_dec)), jnp.int32),
        }
    if cfg.family == "vlm":
        n_img = cfg.n_img_tokens
        S_text = max(4, seq - n_img)
        return {
            "tokens": jnp.asarray(rng.integers(0, V, (batch, S_text)), jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.normal(0, 1, (batch, n_img, cfg.d_model)), jnp.bfloat16
            ),
            "labels": jnp.asarray(rng.integers(0, V, (batch, S_text)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, V, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, V, (batch, seq)), jnp.int32),
    }
