"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend (stubbed: input_specs
supplies patch embeddings).  [hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, head_dim=96, d_ff=8192,
    vocab_size=32064, n_img_tokens=256,
)

SMOKE = ModelConfig(
    name="phi3v-smoke", family="vlm",
    n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=512, n_img_tokens=8,
)

ARCH = ArchDef(
    arch_id="phi-3-vision-4.2b", config=CONFIG, smoke=SMOKE,
    optimizer="adamw", grad_accum=4, skip_shapes=FULL_ATTN_SKIP,
)
