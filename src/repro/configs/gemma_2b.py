"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
GeGLU, head_dim=256, MQA.  [arXiv:2403.08295; hf]
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384,
    vocab_size=256000, act="gelu", glu=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=1, head_dim=32, d_ff=128,
    vocab_size=512, act="gelu", glu=True, tie_embeddings=True,
)

ARCH = ArchDef(
    arch_id="gemma-2b", config=CONFIG, smoke=SMOKE,
    optimizer="adamw", grad_accum=4, skip_shapes=FULL_ATTN_SKIP,
)
