"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from .base import ArchDef, input_specs, decode_operand_specs, smoke_batch
from . import (
    whisper_base, zamba2_2p7b, qwen3_8b, llama3_405b, gemma_2b,
    granite_3_2b, phi3_vision_4p2b, mamba2_130m, qwen2_moe_a2p7b, kimi_k2_1t,
)

ARCHS: Dict[str, ArchDef] = {
    mod.ARCH.arch_id: mod.ARCH
    for mod in (
        whisper_base, zamba2_2p7b, qwen3_8b, llama3_405b, gemma_2b,
        granite_3_2b, phi3_vision_4p2b, mamba2_130m, qwen2_moe_a2p7b,
        kimi_k2_1t,
    )
}


def get_arch(arch_id: str) -> ArchDef:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


__all__ = ["ARCHS", "get_arch", "ArchDef", "input_specs", "decode_operand_specs", "smoke_batch"]
