"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=12288,
    vocab_size=151936, qk_norm=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512, qk_norm=True,
)

ARCH = ArchDef(
    arch_id="qwen3-8b", config=CONFIG, smoke=SMOKE,
    optimizer="adamw", grad_accum=4, skip_shapes=FULL_ATTN_SKIP,
)
