"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared.  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 experts % 16 != 0, so EP falls back to TP inside experts (the expert
``mlp`` axis shards over model — see DESIGN.md §6 / sharding sanitizer).
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408,
    vocab_size=151936,
    n_experts=60, n_shared_experts=4, moe_top_k=4, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32,
    vocab_size=512,
    n_experts=8, n_shared_experts=2, moe_top_k=2, capacity_factor=1.25,
)

ARCH = ArchDef(
    arch_id="qwen2-moe-a2.7b", config=CONFIG, smoke=SMOKE,
    # deeper accumulation bounds the (E, C, D) dispatch buffers (60 experts
    # don't shard over the 16-wide model axis => buffers replicate)
    optimizer="adamw", grad_accum=8, skip_shapes=FULL_ATTN_SKIP,
)
