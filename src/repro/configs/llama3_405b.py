"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA 128k vocab.  [arXiv:2407.21783; unverified]

405B params: bf16 params + Adafactor (factored stats) + microbatched
gradient accumulation keep the train cell inside 16 GB/chip (DESIGN.md §6).
"""
from ..models.config import ModelConfig
from .base import ArchDef, FULL_ATTN_SKIP

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, head_dim=128, d_ff=53248,
    vocab_size=128256, rope_theta=5e5,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke", family="dense",
    n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=512,
)

ARCH = ArchDef(
    arch_id="llama3-405b", config=CONFIG, smoke=SMOKE,
    optimizer="adafactor", grad_accum=16, skip_shapes=FULL_ATTN_SKIP,
)
