"""The per-server experience store (DESIGN.md §17.1).

One record per dataset fingerprint: the meta-feature vector (noted at job
admission), the best observed validation accuracy of every trial spec at
every successive-halving rung (fed by the scheduler's rung records), and
the sub-AutoML winner spec.  Together the records form the performance
matrix the portfolio builder maximizes coverage over.

Persistence contract: ``state_dict()`` is a pure ``service/wire``-safe tree
(strings, floats, ``PipelineSpec`` dataclasses, float32 arrays) and
``load_state(state_dict())`` reproduces the store bit-identically —
accuracies compare ``==``, feature vectors compare bytewise — so a restored
scheduler makes byte-for-byte the same portfolio decisions as the one that
took the snapshot.  The scheduler embeds it in ``snapshot()`` payloads
(wire version 3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..automl.engine import PipelineSpec

__all__ = ["ExperienceRecord", "ExperienceStore"]


@dataclasses.dataclass
class ExperienceRecord:
    """Everything the fleet has learned about one dataset fingerprint."""
    fingerprint: str
    # meta-feature vector (meta/features.py), set at first admission
    features: Optional[np.ndarray] = None
    # spec -> {rung index -> best observed val accuracy at that rung}
    rung_accs: Dict[PipelineSpec, Dict[int, float]] = dataclasses.field(
        default_factory=dict)
    # the sub-AutoML winner spec, once a job on this fingerprint finished
    winner: Optional[PipelineSpec] = None
    jobs: int = 0          # jobs admitted on this fingerprint

    def final_acc(self, spec: PipelineSpec) -> Optional[float]:
        """The spec's accuracy at its deepest observed rung (the number the
        portfolio objective scores — deeper rungs train longer)."""
        accs = self.rung_accs.get(spec)
        if not accs:
            return None
        return accs[max(accs)]


class ExperienceStore:
    """Fingerprint-keyed experience records with bit-identical round trips."""

    def __init__(self):
        self.records: Dict[str, ExperienceRecord] = {}

    # -- feeding ------------------------------------------------------------

    def _record(self, fingerprint: str) -> ExperienceRecord:
        rec = self.records.get(fingerprint)
        if rec is None:
            rec = self.records[fingerprint] = ExperienceRecord(fingerprint)
        return rec

    def note_meta(self, fingerprint: str, features: np.ndarray) -> None:
        """Register a dataset's meta-feature vector (idempotent — the
        vector is a pure function of the fingerprint)."""
        rec = self._record(fingerprint)
        if rec.features is None:
            rec.features = np.asarray(features, dtype=np.float32)
        rec.jobs += 1

    def note_trial(self, fingerprint: str, spec: PipelineSpec, rung_i: int,
                   acc: float) -> None:
        """Record one scored trial; keeps the best accuracy per (spec, rung)."""
        accs = self._record(fingerprint).rung_accs.setdefault(spec, {})
        prev = accs.get(int(rung_i))
        if prev is None or acc > prev:
            accs[int(rung_i)] = float(acc)

    def note_winner(self, fingerprint: str, spec: PipelineSpec) -> None:
        self._record(fingerprint).winner = spec

    # -- querying -----------------------------------------------------------

    def trained(self, exclude: Iterable[str] = ()) -> List[str]:
        """Fingerprints with a finished sub-AutoML pass (winner known) and a
        meta-feature vector, sorted — the usable history."""
        skip = set(exclude)
        return sorted(fp for fp, rec in self.records.items()
                      if rec.winner is not None and rec.features is not None
                      and fp not in skip)

    def n_trained(self, exclude: Iterable[str] = ()) -> int:
        return len(self.trained(exclude))

    def matrix(self, fingerprints: Optional[Sequence[str]] = None,
               ) -> Dict[PipelineSpec, Dict[str, float]]:
        """The performance matrix over ``fingerprints`` (default: all
        trained history): spec -> {fingerprint -> deepest-rung accuracy}."""
        fps = self.trained() if fingerprints is None else list(fingerprints)
        out: Dict[PipelineSpec, Dict[str, float]] = {}
        for fp in fps:
            rec = self.records.get(fp)
            if rec is None:
                continue
            for spec in rec.rung_accs:
                acc = rec.final_acc(spec)
                if acc is not None:
                    out.setdefault(spec, {})[fp] = acc
        return out

    # -- persistence (wire-safe, bit-identical) -----------------------------

    def state_dict(self) -> dict:
        """A ``service/wire``-serializable snapshot of the whole store."""
        return {"records": [self.records[fp]
                            for fp in sorted(self.records)]}

    def load_state(self, state: dict) -> None:
        """Restore ``state_dict()`` output (replaces current contents)."""
        self.records = {}
        for rec in state["records"]:
            self.records[rec.fingerprint] = ExperienceRecord(
                fingerprint=rec.fingerprint,
                features=(None if rec.features is None
                          else np.asarray(rec.features, dtype=np.float32)),
                rung_accs={spec: {int(r): float(a) for r, a in accs.items()}
                           for spec, accs in rec.rung_accs.items()},
                winner=rec.winner,
                jobs=int(rec.jobs),
            )
