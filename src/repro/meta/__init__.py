"""Cross-tenant meta-learning (DESIGN.md §17): portfolio warm-starts.

Every served job leaves behind training data for the next one — the
(dataset fingerprint × trial spec → rung accuracies) performance matrix the
scheduler's rung records accumulate.  This package turns that history into
rung-0 seed trials, PoSH-style (AAD Freiburg's PoSH Auto-sklearn is the
exemplar):

- ``store``     — the per-server :class:`ExperienceStore`: per-fingerprint
                  rung accuracies, winner specs, and meta-feature vectors,
                  persisted bit-identically through scheduler snapshots.
- ``features``  — dataset meta-features from the already-factorized
                  ``CodedDataset`` (n, d, class skew, entropy profile), no
                  new passes over the raw data.
- ``portfolio`` — the deterministic greedy submodular portfolio builder
                  (maximize covered-dataset best accuracy) and the k-NN
                  meta-feature slice that picks which history a new job
                  warm-starts from.
"""
from .features import META_FEATURE_NAMES, meta_features
from .portfolio import (
    greedy_portfolio, knn_fingerprints, portfolio_coverage, portfolio_for,
    spec_sort_key,
)
from .store import ExperienceRecord, ExperienceStore

__all__ = [
    "ExperienceRecord", "ExperienceStore",
    "META_FEATURE_NAMES", "meta_features",
    "greedy_portfolio", "knn_fingerprints", "portfolio_coverage",
    "portfolio_for", "spec_sort_key",
]
