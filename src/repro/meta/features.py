"""Dataset meta-features for the experience store (DESIGN.md §17.2).

The k-NN slice of the portfolio builder needs a cheap vector describing
"what kind of dataset is this?".  Everything here is derived from the
*already factorized* ``CodedDataset`` the scheduler computes at admission —
shapes, the per-column code cardinalities, the target-column class
distribution, and the per-column entropy profile via the same jitted
``measures.full_column_entropy`` the Gen-DST phase precomputes its
reference ``F(D)`` terms with.  No new passes over the raw data, and (for
jobs that also run a DST search) no new jit tracings: the entropy call
shares the DST phase's ``(N, M, B)`` trace.
"""
from __future__ import annotations

import numpy as np

from ..core.measures import CodedDataset, full_column_entropy

__all__ = ["META_FEATURE_NAMES", "meta_features"]

# one name per slot of the vector ``meta_features`` returns, in order
META_FEATURE_NAMES = (
    "log1p_rows",          # log1p(N)
    "log1p_cols",          # log1p(M) (feature columns, target excluded)
    "n_classes",           # target-column cardinality
    "class_skew",          # max class frequency (1/k balanced .. 1.0 degenerate)
    "class_entropy",       # Shannon entropy (log2) of the class distribution
    "col_entropy_mean",    # mean per-column code entropy (target excluded)
    "col_entropy_std",     # std of the per-column code entropies
    "log2_mean_bins",      # log2 of the mean per-column code cardinality
)


def meta_features(coded: CodedDataset) -> np.ndarray:
    """The ``(len(META_FEATURE_NAMES),)`` float32 meta-feature vector.

    Deterministic function of the factorized codes — two datasets with the
    same fingerprint always produce bit-identical vectors, so k-NN
    decisions survive snapshot/restore exactly."""
    codes = np.asarray(coded.codes)
    n_bins = np.asarray(coded.n_bins)
    N, M = codes.shape
    t = int(coded.target_col)

    k = max(int(n_bins[t]), 1)
    counts = np.bincount(codes[:, t], minlength=k).astype(np.float64)
    p = counts / max(counts.sum(), 1.0)
    nz = p[p > 0.0]
    class_entropy = float(-(nz * np.log2(nz)).sum()) if nz.size else 0.0
    class_skew = float(p.max()) if p.size else 1.0

    h = np.asarray(full_column_entropy(coded.codes, coded.max_bins),
                   dtype=np.float64)                      # (M,)
    feat = np.ones(M, dtype=bool)
    feat[t] = False
    hf = h[feat] if feat.any() else h
    bins_f = n_bins[feat].astype(np.float64) if feat.any() else \
        n_bins.astype(np.float64)

    return np.array([
        np.log1p(float(N)),
        np.log1p(float(feat.sum())),
        float(k),
        class_skew,
        class_entropy,
        float(hf.mean()),
        float(hf.std()),
        float(np.log2(max(bins_f.mean(), 1.0))),
    ], dtype=np.float32)
