"""Deterministic greedy submodular portfolio builder (DESIGN.md §17.3).

PoSH-style (SNIPPETS.md Snippet 1): given the experience store's
performance matrix, greedily pick the ``k`` trial specs maximizing the
*covered-dataset best accuracy*

    F(P) = sum over datasets d of max(0, max_{s in P} acc[s][d])

— a monotone submodular set function, so greedy is within (1 - 1/e) of the
optimal portfolio.  A new job does not score against the whole history: the
k-NN slice in meta-feature space picks the most similar stored datasets
first, and the portfolio is built over that slice.

Every choice point is deterministic and independent of history insertion
order: candidate specs are visited in ``spec_sort_key`` order, datasets in
sorted-fingerprint order, and k-NN ties break toward the lexically smaller
fingerprint — permuting the order jobs were served in never changes the
seeds a new job receives (property-tested in tests/test_meta.py).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..automl.engine import PipelineSpec
from .store import ExperienceStore

__all__ = ["spec_sort_key", "greedy_portfolio", "portfolio_coverage",
           "knn_fingerprints", "portfolio_for"]


def spec_sort_key(spec: PipelineSpec) -> tuple:
    """Total deterministic order over pipeline specs (tie-break order).

    ``hp`` values mix ints/floats/strings across families, so the hp leg
    compares by ``repr`` — stable, total, and value-faithful."""
    return (spec.family, spec.preproc, float(spec.feature_frac),
            repr(spec.hp))


def _covered(matrix: Dict[PipelineSpec, Dict[str, float]],
             chosen: Sequence[PipelineSpec], fps: Sequence[str],
             ) -> Dict[str, float]:
    best = {fp: 0.0 for fp in fps}
    for spec in chosen:
        for fp, acc in matrix.get(spec, {}).items():
            if fp in best and acc > best[fp]:
                best[fp] = acc
    return best


def portfolio_coverage(matrix: Dict[PipelineSpec, Dict[str, float]],
                       chosen: Sequence[PipelineSpec]) -> float:
    """F(chosen): summed covered best accuracy over the matrix's datasets."""
    fps = sorted({fp for accs in matrix.values() for fp in accs})
    return float(sum(_covered(matrix, chosen, fps).values()))


def greedy_portfolio(matrix: Dict[PipelineSpec, Dict[str, float]],
                     k: int) -> List[PipelineSpec]:
    """Greedy max-coverage portfolio of (up to) ``k`` specs.

    Each round adds the spec with the largest marginal coverage gain;
    ties — including the zero-gain tail once the matrix is covered — break
    toward the ``spec_sort_key``-smaller spec, so the result is a pure
    function of the matrix *contents*.  Always returns
    ``min(k, len(matrix))`` specs: zero-gain picks still seed useful rung-0
    trials (they were strong somewhere in history)."""
    specs = sorted(matrix, key=spec_sort_key)
    fps = sorted({fp for accs in matrix.values() for fp in accs})
    best = {fp: 0.0 for fp in fps}
    chosen: List[PipelineSpec] = []
    remaining = list(specs)
    for _ in range(min(max(k, 0), len(specs))):
        gains = []
        for s in remaining:
            gain = sum(max(acc - best[fp], 0.0)
                       for fp, acc in matrix[s].items() if fp in best)
            gains.append(gain)
        gi = int(np.argmax(gains))       # first max: sort-order tie-break
        pick = remaining.pop(gi)
        chosen.append(pick)
        for fp, acc in matrix[pick].items():
            if fp in best and acc > best[fp]:
                best[fp] = acc
    return chosen


def knn_fingerprints(features_by_fp: Dict[str, np.ndarray],
                     query: np.ndarray, k: int) -> List[str]:
    """The ``k`` stored fingerprints nearest ``query`` in meta-feature
    space (Euclidean; distance ties break toward the smaller fingerprint)."""
    q = np.asarray(query, dtype=np.float64)
    scored = sorted(
        (float(np.linalg.norm(np.asarray(f, dtype=np.float64) - q)), fp)
        for fp, f in features_by_fp.items())
    return [fp for _dist, fp in scored[:max(k, 0)]]


def portfolio_for(store: ExperienceStore,
                  features: Optional[np.ndarray], *,
                  k: int, knn: int,
                  exclude: Iterable[str] = ()) -> List[PipelineSpec]:
    """The rung-0 seed portfolio for a new dataset.

    Slices the store to the ``knn`` nearest trained fingerprints (all of
    them when ``features`` is None or ``knn`` covers the history), then
    builds the greedy portfolio over that slice.  Empty when the store has
    no usable history."""
    trained = store.trained(exclude)
    if not trained:
        return []
    if features is not None and 0 < knn < len(trained):
        feats = {fp: store.records[fp].features for fp in trained}
        trained = knn_fingerprints(feats, features, knn)
    return greedy_portfolio(store.matrix(trained), k)
