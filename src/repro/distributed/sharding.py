"""Logical-axis sharding rules (MaxText-style) for params, batches, caches.

Every parameter leaf name maps to a tuple of logical axis names
(``LOGICAL_AXES``); stacked layer/group dims contribute a leading ``layers``
axis.  A *rule set* maps logical axes to mesh axes.  Spec resolution
sanitizes against the actual mesh and leaf shape:

  * an axis is only applied if the dim size is divisible by the mesh axes'
    total size;
  * a mesh axis never appears twice in one PartitionSpec (first wins).

Rule sets are chosen per (arch, mode): train uses FSDP over ``data`` for
big models + TP over ``model``; serve uses 2D weight sharding for the
>=100B archs so parameters fit without a data-axis replica (DESIGN.md §6).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

__all__ = [
    "LOGICAL_AXES", "RuleSet", "rules_for", "param_specs", "batch_specs",
    "cache_specs", "tree_shardings", "data_axes",
]

# leaf name -> logical axes (excluding any leading stacked 'layers' dims)
LOGICAL_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / heads
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # attention
    "q": ("embed", "heads", "head_dim"),
    "k": ("embed", "kv_heads", "head_dim"),
    "v": ("embed", "kv_heads", "head_dim"),
    "out": ("heads", "head_dim", "embed"),
    "q_norm": ("head_dim",),
    "k_norm": ("head_dim",),
    # dense mlp
    "gate": ("embed", "mlp"),
    "up": ("embed", "mlp"),
    "down": ("mlp", "embed"),
    # moe
    "router": ("embed", "experts"),
    "e_gate": ("experts", "embed", "mlp"),
    "e_up": ("experts", "embed", "mlp"),
    "e_down": ("experts", "mlp", "embed"),
    "shared_gate": ("embed", None),
    # ssm
    "in_proj": ("embed", "ssm_inner"),
    "out_proj": ("ssm_inner", "embed"),
    "conv_w": (None, "ssm_conv"),
    "conv_b": ("ssm_conv",),
    "A_log": ("ssm_heads",),
    "D_skip": ("ssm_heads",),
    "dt_bias": ("ssm_heads",),
    "gated_norm": ("ssm_inner",),
    # norms
    "ln1": ("embed",), "ln2": ("embed",), "ln_x": ("embed",),
    "norm": ("embed",), "final_norm": ("embed",), "enc_norm": ("embed",),
}


class RuleSet(dict):
    """logical axis -> mesh axis name | tuple of names | None."""


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def rules_for(cfg: ModelConfig, mesh: Mesh, mode: str) -> RuleSet:
    """Resolve the rule set for an (arch, mode).  mode: train|prefill|decode."""
    dax = data_axes(mesh)
    big = param_count_estimate(cfg) >= 2e9       # FSDP / 2D-sharding threshold

    rules = RuleSet({
        "batch": dax,
        "seq": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_conv": "model",
        "kv_seq": None,
        "embed": None,
        "layers": None,
    })
    if mode == "train":
        # FSDP: shard the embed axis of weights over data for big models
        if big:
            rules["embed"] = dax if len(dax) == 1 else "data"
    else:
        # serving: 2D weight sharding once a TP-only replica stops being
        # cheap (params/bf16 over the model axis > ~a quarter of HBM)
        if big:
            rules["embed"] = "data"
    return rules


def param_count_estimate(cfg: ModelConfig) -> float:
    """Rough parameter count from the config (for rule thresholds)."""
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("dense", "vlm"):
        attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim * 2
        mlp = D * cfg.d_ff * (3 if cfg.glu else 2)
        return emb + L * (attn + mlp)
    if cfg.family == "moe":
        attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim * 2
        moe = cfg.n_experts * D * cfg.d_ff * 3 + cfg.n_shared_experts * D * cfg.d_ff * 3
        return emb + L * (attn + moe)
    if cfg.family == "ssm":
        blk = D * (2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads)
        return emb + L * (blk + cfg.d_inner * D)
    if cfg.family == "hybrid":
        blk = D * (2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads)
        attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim * 2 + D * cfg.d_ff * 3
        return emb + L * (blk + cfg.d_inner * D) + attn
    if cfg.family == "encdec":
        attn = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim * 2
        mlp = D * cfg.d_ff * (3 if cfg.glu else 2)
        return emb + (cfg.n_enc_layers + L) * (attn + mlp) + L * attn
    return emb


# ---------------------------------------------------------------------------
# spec resolution
# ---------------------------------------------------------------------------


def _axes_sizes(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    return int(np.prod([mesh.shape[a] for a in entry]))


def _sanitize(spec_axes, shape, mesh: Mesh):
    """Apply divisibility + no-duplicate-mesh-axis constraints.

    Tuple entries fall back to the longest prefix whose total size divides
    the dim (e.g. batch=128 over ('data','model')=(16,16) shards over data)."""
    used = set()
    out = []
    for dim, entry in zip(shape, spec_axes):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        names = tuple(a for a in names if a in mesh.axis_names and a not in used)
        while names:
            size = int(np.prod([mesh.shape[a] for a in names]))
            if size > 1 and dim % size == 0:
                break
            names = names[:-1]
        if not names:
            out.append(None)
            continue
        used.update(names)
        out.append(names[0] if len(names) == 1 else names)
    return P(*out)


def _logical_for_leaf(path: Tuple, leaf) -> Tuple[Optional[str], ...]:
    """Map a pytree path to logical axes, padding leading stacked dims."""
    name = None
    for entry in reversed(path):
        key = getattr(entry, "key", None) or getattr(entry, "name", None)
        if isinstance(key, str):
            name = key
            break
    if name is None or name not in LOGICAL_AXES:
        # KVCache NamedTuple fields: k/v handled above; fallback replicate
        return (None,) * leaf.ndim
    axes = LOGICAL_AXES[name]
    pad = leaf.ndim - len(axes)
    if pad < 0:
        return (None,) * leaf.ndim
    return ("layers",) * pad + axes


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh, rules: RuleSet):
    """PartitionSpec tree for a parameter pytree (works on ShapeDtypeStructs)."""
    def spec_for(path, leaf):
        logical = _logical_for_leaf(path, leaf)
        entries = [rules.get(ax) if ax else None for ax in logical]
        return _sanitize(entries, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(spec_for, params)


def opt_state_specs(opt_state: Any, params_specs: Any, params: Any, mesh: Mesh):
    """Optimizer state: flat per-leaf lists aligned with params leaves.

    Adam m/v mirror the param spec; adafactor factored stats drop the
    reduced dim's sharding."""
    pspecs = jax.tree.leaves(params_specs, is_leaf=lambda x: isinstance(x, P))
    pshapes = [p.shape for p in jax.tree.leaves(params)]

    def match(st_tree_list):
        out = []
        for st, spec, shape in zip(st_tree_list, pspecs, pshapes):
            if isinstance(st, dict):   # adafactor leaf state
                d = {}
                for k, v in st.items():
                    if k == "vr":
                        d[k] = P(*spec[:-1]) if len(spec) > 0 else P()
                    elif k == "vc":
                        d[k] = P(*(spec[:-2] + spec[-1:])) if len(spec) >= 2 else P()
                    else:
                        d[k] = spec
                out.append(d)
            else:
                out.append(spec)
        return out

    return {k: match(v) for k, v in opt_state.items()}


def batch_specs(batch: Any, mesh: Mesh, rules: RuleSet):
    """Shard batch dict: leading dim = batch, rest replicated (seq etc.)."""
    def spec_for(path, leaf):
        if leaf.ndim == 0:
            return P()
        entries = [rules.get("batch")] + [None] * (leaf.ndim - 1)
        return _sanitize(entries, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_specs(cache: Any, cfg: ModelConfig, mesh: Mesh, rules: RuleSet):
    """KV caches: (L, B, S, K, hd); SSM states: conv (L,B,K-1,Cd), h (L,B,H,P,N)."""
    def spec_for(path, leaf):
        if leaf.ndim == 5:    # stacked KV cache or ssm h-state
            # disambiguate by trailing dim: kv head_dim vs ssm state
            if cfg.ssm_state and leaf.shape[-1] == cfg.ssm_state and \
                    leaf.shape[-2] == cfg.ssm_head_dim:
                entries = [None, rules.get("batch"), rules.get("ssm_heads"), None, None]
                return _sanitize(entries, leaf.shape, mesh)
            # KV cache (L, B, S, K, hd): prefer head sharding; if the kv
            # heads don't divide the model axis, shard the SEQUENCE instead
            # (flash-decoding style — XLA partial-softmax via psum).
            kv_ax = rules.get("kv_heads")
            ax_size = _axes_sizes(mesh, kv_ax)
            if kv_ax is not None and leaf.shape[3] % max(ax_size, 1) == 0 and ax_size > 1:
                entries = [None, rules.get("batch"), rules.get("kv_seq"), kv_ax, None]
            else:
                entries = [None, rules.get("batch"), "model", None, None]
            return _sanitize(entries, leaf.shape, mesh)
        if leaf.ndim == 4:    # ssm conv state (L, B, K-1, Cd)
            entries = [None, rules.get("batch"), None, rules.get("ssm_conv")]
            return _sanitize(entries, leaf.shape, mesh)
        if leaf.ndim == 0:
            return P()
        entries = [None, rules.get("batch")] + [None] * (leaf.ndim - 2)
        return _sanitize(entries, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(spec_for, cache)


def tree_shardings(spec_tree: Any, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
