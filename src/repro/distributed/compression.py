"""Gradient compression: error-feedback int8 quantization + a compressed
all-reduce built from shard_map collectives.

``compressed_psum`` implements the classic int8 ring-style all-reduce:
  1. split the (flattened) gradient into one chunk per device;
  2. ``all_to_all`` the *quantized* chunks (wire bytes / 4 vs f32);
  3. locally dequantize + reduce the owned chunk;
  4. re-quantize and ``all_gather`` the reduced chunks (again int8).
Wire traffic ~ 0.5x tensor size vs 2x for a plain f32 ring all-reduce.

``ErrorFeedback`` keeps the classic residual so the quantization error is
re-injected next step (convergence-preserving; Karimireddy et al.).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ErrorFeedback",
           "ef_compress", "compressed_psum"]


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


class ErrorFeedback(NamedTuple):
    residual: jax.Array


def ef_compress(g: jax.Array, ef: ErrorFeedback):
    """Error-feedback quantize: returns (q, scale, new_ef)."""
    corrected = g.astype(jnp.float32) + ef.residual
    q, scale = quantize_int8(corrected)
    new_res = corrected - dequantize_int8(q, scale)
    return q, scale, ErrorFeedback(new_res)


def compressed_psum(x: jax.Array, axis_name: str):
    """int8-transport all-reduce over ``axis_name`` (call inside shard_map).

    x: (N,) f32 with N divisible by the axis size."""
    # jax.lax.axis_size is only in newer jax; psum(1) is the portable spelling
    k = (jax.lax.axis_size(axis_name) if hasattr(jax.lax, "axis_size")
         else jax.lax.psum(1, axis_name))
    n = x.shape[0]
    chunks = x.reshape(k, n // k)
    q, scale = quantize_int8(chunks)                       # int8 (k, n/k)
    # each device receives everyone's copy of its owned chunk
    q_t = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)                  # (k, n/k) int8
    scales = jax.lax.all_gather(scale, axis_name)          # (k,)
    owned = jnp.sum(q_t.astype(jnp.float32) * scales[:, None], axis=0)  # (n/k,)
    q2, s2 = quantize_int8(owned)
    gathered = jax.lax.all_gather(q2, axis_name)           # (k, n/k) int8
    s_all = jax.lax.all_gather(s2, axis_name)              # (k,)
    return (gathered.astype(jnp.float32) * s_all[:, None]).reshape(n)
