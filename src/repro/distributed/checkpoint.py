"""Sharded, atomic, integrity-checked checkpointing with elastic restore.

Layout:
  <dir>/step_<N>/
      manifest.json      — tree structure, shapes, dtypes, per-leaf sha256
      leaf_<i>.npy       — one file per pytree leaf
      COMMIT             — written last; a checkpoint without it is ignored

Writes go to ``step_<N>.tmp`` and are atomically renamed, so a crash mid-
write never corrupts the latest checkpoint.  ``restore_latest`` verifies
hashes and falls back to the previous complete checkpoint on mismatch.
``restore_resharded`` re-places the arrays onto a *different* mesh/sharding
(elastic scaling: grow/shrink the pod between runs).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_latest", "restore_latest_untyped",
           "restore_resharded", "latest_step", "CheckpointManager"]


def _tree_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


_NATIVE_DTYPES = {
    "float64", "float32", "float16", "int64", "int32", "int16", "int8",
    "uint64", "uint32", "uint16", "uint8", "bool",
}


def _native(dtype) -> bool:
    return str(dtype) in _NATIVE_DTYPES


def _restore_dtype(arr: np.ndarray, dtype_str: str, shape) -> np.ndarray:
    if _native(dtype_str):
        return arr
    import ml_dtypes
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_str))).reshape(shape)


def save_checkpoint(ckpt_dir, step: int, state: Any, *, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _tree_paths(state)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i}.npy"
        # custom dtypes (bfloat16, float8) round-trip as uint8 views; the
        # logical dtype is recorded in the manifest
        np.save(tmp / fname, arr if _native(arr.dtype) else arr.view(np.uint8))
        digest = hashlib.sha256((tmp / fname).read_bytes()).hexdigest()
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "sha256": digest}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_????????")
                   if (p / "COMMIT").exists())
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_????????")
             if (p / "COMMIT").exists()]
    return max(steps) if steps else None


def _load_dir(path: Path, template: Any, verify: bool = True):
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _tree_paths(template)
    if len(manifest["leaves"]) != len(leaves):
        raise ValueError("checkpoint/template leaf count mismatch")
    out = []
    for rec, tmpl in zip(manifest["leaves"], leaves):
        f = path / rec["file"]
        if verify:
            digest = hashlib.sha256(f.read_bytes()).hexdigest()
            if digest != rec["sha256"]:
                raise IOError(f"hash mismatch in {f}")
        arr = _restore_dtype(np.load(f), rec["dtype"], rec["shape"])
        if list(arr.shape) != list(rec["shape"]) or \
                list(arr.shape) != list(tmpl.shape):
            raise ValueError(f"shape mismatch {arr.shape} vs {tmpl.shape}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out), manifest["step"]


def restore_latest(ckpt_dir, template: Any, *, verify: bool = True) -> Optional[Tuple[Any, int]]:
    """Restore the newest complete, integrity-valid checkpoint.

    Corrupt checkpoints are skipped (fall back to older ones)."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        (int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_????????")
         if (p / "COMMIT").exists()),
        reverse=True,
    )
    for s in steps:
        try:
            tree, step = _load_dir(ckpt_dir / f"step_{s:08d}", template, verify)
            return jax.tree.map(
                lambda arr, t: jax.numpy.asarray(arr, t.dtype), tree, template
            ), step
        except (IOError, ValueError):
            continue
    return None


def restore_latest_untyped(ckpt_dir, *, verify: bool = True):
    """Restore the newest complete checkpoint *without* a pytree template.

    Returns ``(leaves, step)`` with the leaves as host arrays in manifest
    order — for callers whose checkpointed state is an opaque blob whose
    shape cannot be known before reading it (the serving tier checkpoints
    its wire-encoded scheduler state as one variable-length uint8 leaf, so
    the template-shape contract of ``restore_latest`` cannot apply).
    Corrupt checkpoints are skipped in favour of older complete ones."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        (int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_????????")
         if (p / "COMMIT").exists()),
        reverse=True,
    )
    for s in steps:
        path = ckpt_dir / f"step_{s:08d}"
        try:
            manifest = json.loads((path / "manifest.json").read_text())
            leaves = []
            for rec in manifest["leaves"]:
                f = path / rec["file"]
                if verify:
                    digest = hashlib.sha256(f.read_bytes()).hexdigest()
                    if digest != rec["sha256"]:
                        raise IOError(f"hash mismatch in {f}")
                arr = _restore_dtype(np.load(f), rec["dtype"], rec["shape"])
                if list(arr.shape) != list(rec["shape"]):
                    raise ValueError(
                        f"shape mismatch {arr.shape} vs {rec['shape']}")
                leaves.append(arr)
            return leaves, manifest["step"]
        except (IOError, ValueError, KeyError, json.JSONDecodeError):
            continue
    return None


def restore_resharded(ckpt_dir, template: Any, shardings: Any) -> Optional[Tuple[Any, int]]:
    """Elastic restore: place each leaf with the given (new-mesh) shardings.

    ``shardings`` is a pytree of jax.sharding.Sharding matching ``template``."""
    res = restore_latest(ckpt_dir, template)
    if res is None:
        return None
    tree, step = res
    placed = jax.tree.map(
        lambda arr, sh: jax.device_put(np.asarray(arr), sh), tree, shardings
    )
    return placed, step


class CheckpointManager:
    """Async checkpointing: snapshots to host, writes on a worker thread —
    the train loop never blocks on disk."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, state: Any):
        host_state = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), state)
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint, args=(self.dir, step, host_state),
            kwargs={"keep": self.keep}, daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
