"""Fault tolerance + straggler mitigation primitives.

* ``assign_shards``: deterministic data-shard -> host assignment that
  rebalances when hosts die or straggle (consistent re-hash: surviving
  hosts keep their shards; orphaned shards spread round-robin).  Every host
  computes the same assignment from the same (step, alive-set) — no
  coordinator needed.
* ``FaultTolerantLoop``: wraps a train loop with periodic checkpointing and
  restart-from-latest semantics; ``simulate_failure_at`` is the test hook.
* ``Heartbeat``: tracks per-host progress timestamps; hosts falling behind
  the p50 by ``straggler_factor`` are marked stragglers (their shards get
  re-assigned next step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .checkpoint import restore_latest, save_checkpoint

__all__ = ["assign_shards", "Heartbeat", "FaultTolerantLoop"]


def assign_shards(n_shards: int, alive_hosts: Sequence[int], all_hosts: int):
    """shard -> host map; stable for surviving hosts, orphans least-loaded.

    Surviving hosts always keep their home shards (``s % all_hosts``); each
    orphaned shard goes to the alive host with the fewest shards so far
    (ties broken by host id — fully deterministic), which keeps the load
    within one shard of balanced instead of piling orphans onto ``alive[0]``.
    """
    alive = sorted(set(alive_hosts))
    if not alive:
        raise ValueError("no alive hosts")
    assignment = {}
    orphans = []
    for s in range(n_shards):
        home = s % all_hosts
        if home in alive:
            assignment[s] = home
        else:
            orphans.append(s)
    loads = {h: 0 for h in alive}
    for h in assignment.values():
        loads[h] += 1
    for s in orphans:
        h = min(alive, key=lambda x: (loads[x], x))
        assignment[s] = h
        loads[h] += 1
    return assignment


@dataclasses.dataclass
class Heartbeat:
    n_hosts: int
    straggler_factor: float = 3.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)
    step_time: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, step_duration: float):
        self.last_seen[host] = time.monotonic()
        self.step_time[host] = step_duration

    def stragglers(self) -> List[int]:
        if len(self.step_time) < 2:
            return []
        med = float(np.median(list(self.step_time.values())))
        return [h for h, t in self.step_time.items()
                if t > self.straggler_factor * max(med, 1e-9)]

    def dead(self, timeout_s: float = 60.0) -> List[int]:
        now = time.monotonic()
        return [h for h, t in self.last_seen.items() if now - t > timeout_s]


class FaultTolerantLoop:
    """Checkpointed train loop with restart-from-latest semantics.

    ``step_fn(state, batch) -> (state, metrics)`` must be deterministic given
    (state, batch) — restart then reproduces the uninterrupted run bit-for-
    bit (verified in tests/test_fault.py)."""

    def __init__(self, step_fn: Callable, batch_fn: Callable, ckpt_dir,
                 ckpt_every: int = 10, keep: int = 3):
        self.step_fn = step_fn
        self.batch_fn = batch_fn            # step -> batch (deterministic)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep

    def run(self, init_state, n_steps: int,
            simulate_failure_at: Optional[int] = None):
        restored = restore_latest(self.ckpt_dir, init_state)
        if restored is not None:
            state, start = restored
            start += 1
        else:
            state, start = init_state, 0
        metrics = None
        for step in range(start, n_steps):
            if simulate_failure_at is not None and step == simulate_failure_at:
                raise RuntimeError(f"simulated node failure at step {step}")
            batch = self.batch_fn(step)
            state, metrics = self.step_fn(state, batch)
            if (step + 1) % self.ckpt_every == 0 or step == n_steps - 1:
                save_checkpoint(self.ckpt_dir, step, state, keep=self.keep)
        return state, metrics
