"""Dataset fingerprinting for the service layer (DESIGN.md §11.1).

A fingerprint is a SHA-256 content hash of the *factorized* dataset — the
integer ``codes`` matrix, the per-column ``n_bins``, and ``target_col`` —
not of the raw float matrix.  Factorization is deterministic (quantile bins
from sorted values, dense code assignment by value order), so two
byte-identical raw datasets always factorize to identical codes, and the
codes are exactly what the DST search consumes: datasets that factorize the
same have the same Gen-DST search problem, which is the equivalence the DST
cache needs.  Shapes are hashed explicitly so a prefix relationship between
two code buffers can never collide.
"""
from __future__ import annotations

import hashlib

import numpy as np

from ..core.measures import CodedDataset

__all__ = ["dataset_fingerprint"]


def dataset_fingerprint(coded: CodedDataset) -> str:
    """Stable hex fingerprint of a factorized dataset."""
    codes = np.ascontiguousarray(np.asarray(coded.codes, dtype=np.int32))
    n_bins = np.ascontiguousarray(np.asarray(coded.n_bins, dtype=np.int32))
    h = hashlib.sha256()
    h.update(np.asarray(codes.shape, np.int64).tobytes())
    h.update(codes.tobytes())
    h.update(n_bins.tobytes())
    h.update(np.int64(coded.target_col).tobytes())
    return h.hexdigest()
