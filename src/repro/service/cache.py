"""LRU DST cache (DESIGN.md §11.2).

Keyed by ``(fingerprint, n, m, measure, search_cfg)`` — the full identity
of a Gen-DST search problem: the factorized dataset content, the requested
subset shape, the preserved measure, and the resolved search configuration
(subsets found by weaker searches must not satisfy stronger requests).
An entry stores the search's *output*
(``row_idx``/``col_mask``/fitness) and, once a job's sub-AutoML pass has
finished, the winning model family, so a repeat submission can skip Gen-DST
entirely and warm-start the restricted fine-tune (scheduler, §11.3).

Entries are immutable snapshots of host numpy arrays; the cache never holds
device buffers.  Capacity is enforced LRU (get refreshes recency).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

__all__ = ["DSTCache", "DSTCacheEntry", "dst_cache_key"]


def dst_cache_key(fingerprint: str, n: int, m: int, measure: str,
                  search_cfg: Optional[Tuple] = None) -> Tuple:
    """The cache key of one Gen-DST search problem.

    ``(fingerprint, n, m, measure)`` identifies *what* subset is sought;
    ``search_cfg`` (any hashable, e.g. the resolved ``GenDSTConfig``)
    identifies *how hard* it was searched for — without it, a subset found
    by a 2-generation toy search would satisfy a later paper-strength
    request for the same dataset."""
    return (fingerprint, int(n), int(m), measure, search_cfg)


@dataclasses.dataclass
class DSTCacheEntry:
    row_idx: np.ndarray            # (n,) host int
    col_mask: np.ndarray           # (M,) host bool
    fitness: float                 # -|F(d) - F(D)| at insert time
    winner_family: Optional[str] = None   # sub-AutoML winner from a prior job
    hits: int = 0


class DSTCache:
    """LRU map from DST search problems to their solved subsets."""

    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError("DSTCache capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[Tuple, DSTCacheEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def peek(self, key) -> Optional[DSTCacheEntry]:
        """Look up without touching recency or hit/miss stats (used by the
        scheduler's warm-wait polling, which is not a cache *use*)."""
        return self._entries.get(key)

    def get(self, key) -> Optional[DSTCacheEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        entry.hits += 1
        return entry

    def put(self, key, entry: DSTCacheEntry) -> DSTCacheEntry:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return entry

    def note_winner(self, key, family: str) -> None:
        """Record the sub-AutoML winner family for warm-started repeats.

        No-op if the entry was evicted meanwhile; does not refresh recency
        (recording a result is not a use of the entry)."""
        entry = self._entries.get(key)
        if entry is not None:
            entry.winner_family = family

    def stats(self) -> dict:
        return {
            "size": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
