"""DST cache with LRU and cost-aware GDSF eviction (DESIGN.md §11.2, §12.5).

Keyed by ``(fingerprint, n, m, measure, search_cfg)`` — the full identity
of a subset-search problem: the factorized dataset content, the requested
subset shape, the preserved measure, and the resolved strategy + options
(subsets found by weaker searches must not satisfy stronger requests; with
the plan API, ``search_cfg`` is the plan's ``(strategy, strategy_opts)``
identity, so *every* registered cacheable strategy shares this cache, not
just Gen-DST).  An entry stores the search's *output*
(``row_idx``/``col_mask``/fitness), its *production cost* in wall seconds,
and, once a job's sub-AutoML pass has finished, the winning model family,
so a repeat submission can skip the subset search entirely and warm-start
the restricted fine-tune (scheduler, §11.3).

Entries are immutable snapshots of host numpy arrays; the cache never holds
device buffers.  Two eviction policies:

- ``policy="lru"`` (default): plain recency order (`get` refreshes).
- ``policy="gdsf"``: Greedy-Dual-Size-Frequency — each entry carries the
  priority ``clock + frequency * cost_s / size_bytes``, refreshed on every
  hit; eviction removes the lowest-priority entry and advances the clock to
  its priority (aging).  A cheap-to-recompute, rarely-hit, byte-heavy
  subset is evicted long before an expensive Gen-DST result of the same
  age — entry production costs span ~4 orders of magnitude between a
  k-means baseline and a paper-strength genetic search.

Both policies enforce the entry-count ``capacity`` and, when set, a
``byte_budget`` over the summed entry payload sizes.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["DSTCache", "DSTCacheEntry", "dst_cache_key"]


def dst_cache_key(fingerprint: str, n: int, m: int, measure: str,
                  search_cfg: Optional[Tuple] = None) -> Tuple:
    """The cache key of one subset-search problem.

    ``(fingerprint, n, m, measure)`` identifies *what* subset is sought;
    ``search_cfg`` (any hashable — the resolved ``GenDSTConfig``, or the
    plan API's ``(strategy, strategy_opts)`` pair) identifies *how* it was
    searched for — without it, a subset found by a 2-generation toy search
    would satisfy a later paper-strength request for the same dataset."""
    return (fingerprint, int(n), int(m), measure, search_cfg)


@dataclasses.dataclass
class DSTCacheEntry:
    row_idx: np.ndarray            # (n,) host int
    col_mask: np.ndarray           # (M,) host bool
    fitness: float                 # -|F(d) - F(D)| at insert time
    winner_family: Optional[str] = None   # sub-AutoML winner from a prior job
    hits: int = 0
    cost_s: float = 0.0            # production cost (strategy wall seconds)

    @property
    def nbytes(self) -> int:
        """Payload size — the GDSF size term and the byte-budget unit."""
        return int(self.row_idx.nbytes) + int(self.col_mask.nbytes)


class DSTCache:
    """Map from DST search problems to their solved subsets.

    ``capacity`` bounds the entry count; ``byte_budget`` (optional) bounds
    the summed payload bytes; ``policy`` picks the victim: ``"lru"``
    recency order or ``"gdsf"`` cost-aware priority (module docstring)."""

    def __init__(self, capacity: int = 128, *,
                 byte_budget: Optional[int] = None, policy: str = "lru"):
        if capacity < 1:
            raise ValueError("DSTCache capacity must be >= 1")
        if policy not in ("lru", "gdsf"):
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             "available policies: gdsf, lru")
        if byte_budget is not None and byte_budget < 1:
            raise ValueError("byte_budget must be >= 1 (or None)")
        self.capacity = capacity
        self.byte_budget = byte_budget
        self.policy = policy
        self._entries: "OrderedDict[Tuple, DSTCacheEntry]" = OrderedDict()
        self._pri: dict = {}           # gdsf: key -> priority
        self._clock = 0.0              # gdsf aging clock
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def _priority(self, entry: DSTCacheEntry) -> float:
        # GDSF: clock + frequency * cost / size.  hits+1 counts the insert
        # itself as one use; the size floor guards empty payloads.
        return self._clock + (entry.hits + 1) * entry.cost_s / max(entry.nbytes, 1)

    def _touch(self, key, entry: DSTCacheEntry) -> None:
        self._entries.move_to_end(key)
        if self.policy == "gdsf":
            self._pri[key] = self._priority(entry)

    def _evict_until_fits(self) -> None:
        while (len(self._entries) > self.capacity
               or (self.byte_budget is not None
                   and self.total_bytes > self.byte_budget
                   and len(self._entries) > 1)):
            if self.policy == "gdsf":
                victim = min(self._pri, key=self._pri.get)
                # aging: future inserts compete against the evicted value
                self._clock = self._pri.pop(victim)
                del self._entries[victim]
            else:
                victim, _ = self._entries.popitem(last=False)
            self.evictions += 1

    def items(self) -> List[Tuple[tuple, DSTCacheEntry]]:
        """Entries in recency order, oldest first — checkpoint iteration
        (re-``put``ting them in this order reproduces the LRU order)."""
        return list(self._entries.items())

    def peek(self, key) -> Optional[DSTCacheEntry]:
        """Look up without touching recency/priority or hit/miss stats (used
        by the scheduler's warm-wait polling, which is not a cache *use*)."""
        return self._entries.get(key)

    def get(self, key) -> Optional[DSTCacheEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        entry.hits += 1
        self._touch(key, entry)
        return entry

    def put(self, key, entry: DSTCacheEntry) -> DSTCacheEntry:
        self._entries[key] = entry
        self._touch(key, entry)
        self._evict_until_fits()
        return entry

    def note_winner(self, key, family: str) -> None:
        """Record the sub-AutoML winner family for warm-started repeats.

        No-op if the entry was evicted meanwhile; does not refresh recency
        (recording a result is not a use of the entry)."""
        entry = self._entries.get(key)
        if entry is not None:
            entry.winner_family = family

    def stats(self) -> dict:
        return {
            "size": len(self._entries),
            "capacity": self.capacity,
            "bytes": self.total_bytes,
            "byte_budget": self.byte_budget,
            "policy": self.policy,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
