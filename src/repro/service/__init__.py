"""SubStrat service layer (DESIGN.md §11): a multi-tenant job server over
the one-shot ``substrat()`` pipeline.

- ``fingerprint`` — stable content hash of a factorized dataset.
- ``cache``       — LRU DST cache keyed by (fingerprint, n, m, measure,
                    search config), so repeat submissions skip Gen-DST and
                    warm-start the restricted fine-tune.
- ``scheduler``   — async job queue running jobs through explicit resumable
                    phases, merging compatible rung cohorts from different
                    jobs into one batched-engine dispatch.
- ``server``      — in-process submit/poll/result front end with per-tenant
                    budget accounting.
"""
from .cache import DSTCache, DSTCacheEntry
from .fingerprint import dataset_fingerprint
from .scheduler import Scheduler, SubStratJob
from .server import BudgetExceeded, JobStatus, SubStratServer

__all__ = [
    "DSTCache", "DSTCacheEntry", "dataset_fingerprint",
    "Scheduler", "SubStratJob",
    "BudgetExceeded", "JobStatus", "SubStratServer",
]
