"""SubStrat service layer (DESIGN.md §11, §14): a multi-tenant job server
over the one-shot ``substrat()`` pipeline.

- ``fingerprint`` — stable content hash of a factorized dataset.
- ``cache``       — LRU DST cache keyed by (fingerprint, n, m, measure,
                    search config), so repeat submissions skip Gen-DST and
                    warm-start the restricted fine-tune.
- ``scheduler``   — async job queue running jobs through explicit resumable
                    phases, merging compatible rung cohorts from different
                    jobs into one batched-engine dispatch; snapshottable.
- ``server``      — in-process submit/poll/result front end with per-tenant
                    budget accounting, token-bucket admission rate limits,
                    and streamed rung leaderboards.
- ``wire``        — versioned binary serialization for everything the
                    transport ships (cohorts, results, scheduler state).
- ``worker``      — per-device worker-process loop (pull task, eval, push).
- ``transport``   — cross-process tier: worker pools, the crash-recovering
                    ``DistributedScheduler``, and the HTTP front end.
"""
from .cache import DSTCache, DSTCacheEntry
from .fingerprint import dataset_fingerprint
from .scheduler import Scheduler, SubStratJob
from .server import (
    BudgetExceeded, JobStatus, RateLimited, SubStratServer, TokenBucket,
)
from .transport import (
    DistributedScheduler, ProcessWorkerPool, SimWorkerPool,
    SubStratHTTPClient, SubStratHTTPServer,
)
from .wire import WireError, WireVersionError

__all__ = [
    "DSTCache", "DSTCacheEntry", "dataset_fingerprint",
    "Scheduler", "SubStratJob",
    "BudgetExceeded", "JobStatus", "RateLimited", "SubStratServer",
    "TokenBucket",
    "DistributedScheduler", "ProcessWorkerPool", "SimWorkerPool",
    "SubStratHTTPClient", "SubStratHTTPServer",
    "WireError", "WireVersionError",
]
