"""In-process SubStrat serving front end (DESIGN.md §11.5).

``SubStratServer`` wraps the scheduler with the three-call serving surface —
``submit`` / ``poll`` / ``result`` — plus per-tenant budget accounting:
every job's phase costs (measured wall seconds; merged rungs charge each
participant its equal share) accrue to the submitting tenant, and a tenant
over its budget gets ``BudgetExceeded`` at the next ``submit``.  Already
admitted jobs always run to completion — admission control, not preemption.

Admission is also *rate*-limited per tenant: each tenant draws from a
token bucket (``rate`` jobs/second refill, ``burst`` capacity) and an
empty bucket gets ``RateLimited`` — carrying ``retry_after_s`` — which the
HTTP transport maps to ``429`` with a ``Retry-After`` header.  Buckets use
an injectable clock so the policy is deterministic under test.

This is deliberately in-process (one Python heap, one device): the
cross-process transport is an open ROADMAP item, and nothing here assumes
more than the scheduler's cooperative ``step()`` loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

from ..core.measures import CodedDataset
from ..core.plan import Plan
from ..core.substrat import SubStratConfig, SubStratResult
from .cache import DSTCache
from .scheduler import Scheduler

__all__ = ["BudgetExceeded", "JobStatus", "RateLimited", "SubStratServer",
           "TenantAccount", "TokenBucket"]


class BudgetExceeded(RuntimeError):
    """Raised by ``submit`` when the tenant has spent its budget."""


class RateLimited(RuntimeError):
    """Raised by ``submit`` when the tenant's token bucket is empty.

    ``retry_after_s`` is the seconds until the bucket refills one token —
    the HTTP layer surfaces it as the ``Retry-After`` header of a 429."""

    def __init__(self, tenant: str, retry_after_s: float):
        super().__init__(
            f"tenant {tenant!r} is rate limited; retry in "
            f"{retry_after_s:.2f}s")
        self.tenant = tenant
        self.retry_after_s = retry_after_s


class TokenBucket:
    """A standard token bucket: ``rate`` tokens/second refill up to
    ``burst`` capacity; each admission costs one token.  The clock is
    injectable (tests drive a fake monotonic clock)."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError("rate and burst must be positive")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._t_last = clock()

    def _refill(self) -> None:
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._t_last) * self.rate)
        self._t_last = now

    @property
    def tokens(self) -> float:
        self._refill()
        return self._tokens

    def try_acquire(self) -> float:
        """Take one token.  Returns 0.0 on success, else the seconds until
        one token is available (nothing is consumed on failure)."""
        self._refill()
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return 0.0
        return (1.0 - self._tokens) / self.rate


@dataclasses.dataclass
class TenantAccount:
    budget_s: Optional[float] = None   # None = unlimited
    spent_s: float = 0.0               # accrued phase seconds (all jobs)
    jobs_submitted: int = 0


@dataclasses.dataclass(frozen=True)
class JobStatus:
    """Snapshot returned by ``poll``."""
    job_id: int
    tenant: str
    phase: str                 # scheduler.PHASES: factorize | dst | warm_wait
                               #   | sub_automl | fine_tune | done | failed
    cache_hit: bool
    warm_started: bool         # cache knew the winner family: sub pass skipped
    times: Dict[str, float]    # per-phase seconds so far (raw ledger keys)
    # the canonical per-phase breakdown (DESIGN.md §15.1): always all four
    # pipeline phases, zero where a phase has not run (or was skipped)
    phase_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    error: Optional[str] = None
    # streamed partial results (DESIGN.md §14.4): the rung-by-rung
    # leaderboard entries recorded since the caller's cursor, plus the
    # total count to use as the next ``poll(since=...)`` cursor
    leaderboard: tuple = ()
    leaderboard_total: int = 0

    @property
    def done(self) -> bool:
        return self.phase == "done"


# JobStatus.phase_times key <- job.times ledger key
_PHASE_TIME_KEYS = (("factorize", "factorize_s"), ("gen_dst", "gen_dst_s"),
                    ("sub_automl", "automl_sub_s"),
                    ("fine_tune", "fine_tune_s"))


class SubStratServer:
    """submit/poll/result over the multi-tenant scheduler."""

    def __init__(
        self,
        *,
        cache_capacity: int = 128,
        cache_byte_budget: Optional[int] = None,
        cache_policy: str = "lru",
        warm_start: bool = True,
        hetero_merge: bool = True,
        megabatch: bool = True,
        waste_budget: float = 4.0,
        hetero_pad_limit: Optional[float] = None,   # deprecated: waste_budget
        batch_dst: bool = False,
        tenant_budgets: Optional[Dict[str, float]] = None,
        scheduler: Optional[Scheduler] = None,
        tenant_rate_limits: Optional[Dict[str, Tuple[float, float]]] = None,
        default_rate_limit: Optional[Tuple[float, float]] = None,
        rate_clock: Callable[[], float] = time.monotonic,
    ):
        # an injected scheduler (e.g. transport.DistributedScheduler) wins;
        # the cache/merge kwargs then belong to its constructor, not ours
        self.scheduler = scheduler if scheduler is not None else Scheduler(
            DSTCache(cache_capacity, byte_budget=cache_byte_budget,
                     policy=cache_policy),
            warm_start=warm_start, hetero_merge=hetero_merge,
            megabatch=megabatch, waste_budget=waste_budget,
            hetero_pad_limit=hetero_pad_limit,
            batch_dst=batch_dst)
        self.tenants: Dict[str, TenantAccount] = {}
        for tenant, budget in (tenant_budgets or {}).items():
            self.tenants[tenant] = TenantAccount(budget_s=budget)
        # per-tenant admission rate limits: tenant -> (rate/s, burst).
        # ``default_rate_limit`` applies to tenants without an explicit
        # entry; None (the default) leaves those tenants unlimited.
        self._rate_limits = dict(tenant_rate_limits or {})
        self._default_rate_limit = default_rate_limit
        self._rate_clock = rate_clock
        self._buckets: Dict[str, TokenBucket] = {}

    # -- tenancy ------------------------------------------------------------

    def _account(self, tenant: str) -> TenantAccount:
        if tenant not in self.tenants:
            self.tenants[tenant] = TenantAccount()
        return self.tenants[tenant]

    def set_budget(self, tenant: str, budget_s: Optional[float]) -> None:
        self._account(tenant).budget_s = budget_s

    def set_rate_limit(self, tenant: str,
                       limit: Optional[Tuple[float, float]]) -> None:
        """(Re)set a tenant's ``(rate/s, burst)`` admission limit; None
        removes it (the tenant falls back to the default limit, if any)."""
        self._buckets.pop(tenant, None)
        if limit is None:
            self._rate_limits.pop(tenant, None)
        else:
            self._rate_limits[tenant] = limit

    def _bucket(self, tenant: str) -> Optional[TokenBucket]:
        bucket = self._buckets.get(tenant)
        if bucket is None:
            limit = self._rate_limits.get(tenant, self._default_rate_limit)
            if limit is None:
                return None
            rate, burst = limit
            bucket = TokenBucket(rate, burst, clock=self._rate_clock)
            self._buckets[tenant] = bucket
        return bucket

    def _check_rate(self, tenant: str) -> None:
        bucket = self._bucket(tenant)
        if bucket is None:
            return
        m = self.scheduler.metrics
        retry_after = bucket.try_acquire()
        m.gauge("rate_limit_tokens",
                "admission tokens remaining in the tenant's bucket",
                ("tenant",)).set(bucket.tokens, tenant=tenant)
        if retry_after > 0.0:
            m.counter("rate_limited_total",
                      "submissions rejected by the tenant rate limiter",
                      ("tenant",)).inc(tenant=tenant)
            raise RateLimited(tenant, retry_after)

    def _refresh_spend(self) -> None:
        for account in self.tenants.values():
            account.spent_s = 0.0
        for job in self.scheduler.jobs.values():
            self._account(job.tenant).spent_s += job.cost_s

    # -- serving surface ----------------------------------------------------

    def submit(
        self,
        X: np.ndarray,
        y: np.ndarray,
        *,
        tenant: str = "default",
        key: Optional[jax.Array] = None,
        plan: Optional[Plan] = None,
        config: Optional[SubStratConfig] = None,
        dst_fn: Optional[Callable] = None,
        coded: Optional[CodedDataset] = None,
        X_test: Optional[np.ndarray] = None,
        y_test: Optional[np.ndarray] = None,
    ) -> int:
        """Admit a job for ``tenant``; returns a job id for poll/result.

        ``plan`` is the native payload (DESIGN.md §12); ``config`` (+ the
        deprecated ``dst_fn``) is converted on admission."""
        self._check_rate(tenant)
        account = self._account(tenant)
        self._refresh_spend()
        if account.budget_s is not None and account.spent_s >= account.budget_s:
            raise BudgetExceeded(
                f"tenant {tenant!r} spent {account.spent_s:.2f}s of its "
                f"{account.budget_s:.2f}s budget")
        account.jobs_submitted += 1
        return self.scheduler.submit(
            X, y, tenant=tenant, key=key, plan=plan, config=config,
            dst_fn=dst_fn, coded=coded, X_test=X_test, y_test=y_test)

    def poll(self, job_id: int, since: int = 0) -> JobStatus:
        """Job status snapshot.  ``since`` is a leaderboard cursor: only
        entries recorded at index >= ``since`` are returned, so a client
        polling with ``since=last.leaderboard_total`` streams each rung's
        standings exactly once instead of poll-until-done."""
        job = self.scheduler.jobs[job_id]
        return JobStatus(
            job_id=job.job_id,
            tenant=job.tenant,
            phase=job.phase,
            cache_hit=job.cache_hit,
            warm_started=job.warm_family is not None,
            times=dict(job.times),
            phase_times={name: float(job.times.get(key, 0.0))
                         for name, key in _PHASE_TIME_KEYS},
            error=None if job.error is None else repr(job.error),
            leaderboard=tuple(job.leaderboard[since:]),
            leaderboard_total=len(job.leaderboard),
        )

    def run(self) -> None:
        """Drive every pending job to completion (cooperative loop)."""
        self.scheduler.run()
        self._refresh_spend()

    def result(self, job_id: int) -> SubStratResult:
        """Block (cooperatively) until ``job_id`` finishes; return its result.

        Other pending jobs advance too — the scheduler has no way to run one
        job's rung without stepping the queue, and stepping the queue is the
        point (merged rungs)."""
        job = self.scheduler.jobs[job_id]
        while job.active:
            self.scheduler.step()
        self._refresh_spend()
        if job.phase == "failed":
            raise RuntimeError(f"job {job_id} failed") from job.error
        return job.result

    def stats(self) -> dict:
        self._refresh_spend()
        out = self.scheduler.stats()
        out["tenants"] = {
            tenant: {"spent_s": acc.spent_s, "budget_s": acc.budget_s,
                     "jobs_submitted": acc.jobs_submitted}
            for tenant, acc in self.tenants.items()
        }
        out["rate_limits"] = {
            tenant: {"rate": limit[0], "burst": limit[1],
                     "tokens": (self._buckets[tenant].tokens
                                if tenant in self._buckets else limit[1])}
            for tenant, limit in sorted(self._rate_limits.items())
        }
        if self._default_rate_limit is not None:
            out["default_rate_limit"] = {
                "rate": self._default_rate_limit[0],
                "burst": self._default_rate_limit[1],
            }
        return out

    # -- observability (DESIGN.md §15) ---------------------------------------

    def metrics_text(self) -> str:
        """Prometheus text exposition: the scheduler's registry plus the
        process-global JAX compile/dispatch counters (``GET /v1/metrics``)."""
        from ..obs import jaxprof
        return self.scheduler.metrics.render() + jaxprof.render_prometheus()

    def trace(self, job_id: int) -> Optional[dict]:
        """One job's recorded spans (JSON-safe), or None for unknown ids."""
        job = self.scheduler.jobs.get(job_id)
        if job is None:
            return None
        return {"job_id": job.job_id, "trace_id": job.trace_id,
                "spans": list(job.spans)}
