"""Worker-process loop of the cross-process serving tier (DESIGN.md §14.3).

A worker is one process pinned to one device: it pulls packed megabatch
tasks from its queue, evaluates them through the same batched engine the
in-process scheduler uses (``eval_trial_megabatch`` /
``eval_rung_cohorts``), and pushes wire-encoded scored results back on the
shared result queue.  Because the evaluation entry points are pure
functions of the cohort payloads, a task re-dispatched to a different
worker after a crash produces bit-identical results — the whole recovery
story rests on that.

Message protocol (queue values are small tuples; large payloads are wire
bytes — see ``service/wire.py``):

  front end -> worker
      ("eval", task_id, wire_bytes, attempt)   evaluate one packed group
      ("stop",)                                drain and exit

  worker -> front end
      ("hello", worker_id, t)              ready (jax imported, loop live)
      ("beat", worker_id, t)               heartbeat: task accepted
      ("done", task_id, worker_id, wire_bytes, dt, spans)
      ("error", task_id, worker_id, repr, traceback, dt, spans)

The ``attempt`` number rides the queue message rather than the wire
payload on purpose: a re-dispatch reuses the already-encoded payload
bytes verbatim, so anything attempt-specific must travel outside them.
``spans`` is a list of plain span dicts (``obs/trace``) covering the
worker's deserialize/eval/serialize legs; the worker derives its parent
dispatch-span id purely from the wire header's trace context plus the
attempt number — no id exchange (DESIGN.md §15.2).

Fault injection: ``worker_main`` takes ``fault_events`` — a tuple of
``(worker_id, task_index, action, seconds)`` primitives (the picklable
compilation target of ``tests/harness/faultsim.FaultPlan``).  When this
worker dequeues its ``task_index``-th task it applies the action first:

- ``"kill"``  — ``os._exit`` before any reply: exactly what a crashed or
  OOM-killed process looks like to the front end;
- ``"stall"`` — sleep ``seconds`` *before* the heartbeat, so the front end
  sees a dispatched task with no beat (the straggler signature);
- ``"delay"`` — sleep ``seconds`` and then run normally (a slow worker,
  not a lost one).

The hook sits at the dequeue point so every recovery path is exercised at
a deterministic step rather than by racing timers.
"""
from __future__ import annotations

import contextlib
import os
import time
import traceback
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..automl.engine import TrialCohort, _materialize_scored
from ..obs import trace
from . import wire

__all__ = ["cohort_payload", "cohort_restore", "eval_task", "handle_eval",
           "worker_main", "KILLED_EXIT_CODE"]

KILLED_EXIT_CODE = 17     # distinguishes injected kills from real crashes


# ---------------------------------------------------------------------------
# cohort <-> wire payload
# ---------------------------------------------------------------------------

# the evaluation-context keys a worker needs; jnp mirrors + caches rebuilt
_CTX_KEYS = ("X_tr", "y_tr", "X_val", "y_val", "n_classes", "seed")


def cohort_payload(tc: TrialCohort) -> dict:
    """The wire-encodable projection of one ``TrialCohort``.

    Ships the raw evaluation data and the per-trial cursors; the worker
    rebuilds the derived context (jnp label mirrors, variant caches) on its
    own device."""
    return {
        "specs": list(tc.specs),
        "tids": [int(t) for t in tc.tids],
        "rung_i": int(tc.rung_i),
        "epochs": int(tc.epochs),
        "collect": bool(tc.collect),
        "rungs": tuple(int(r) for r in tc.trial_rungs),
        "steps": tuple(int(s) for s in tc.trial_steps),
        "ctx": {k: tc.ctx[k] for k in _CTX_KEYS},
    }


def cohort_restore(payload: dict) -> TrialCohort:
    """Rebuild an evaluable ``TrialCohort`` from its wire projection."""
    import jax.numpy as jnp
    ctx = dict(payload["ctx"])
    ctx["X_tr"] = np.asarray(ctx["X_tr"], np.float32)
    ctx["X_val"] = np.asarray(ctx["X_val"], np.float32)
    ctx["y_tr"] = np.asarray(ctx["y_tr"])
    ctx["y_val"] = np.asarray(ctx["y_val"])
    ctx["y_tr_j"] = jnp.asarray(ctx["y_tr"])
    ctx["y_val_j"] = jnp.asarray(ctx["y_val"])
    ctx["n_classes"] = int(ctx["n_classes"])
    ctx["seed"] = int(ctx["seed"])
    ctx["budget_active"] = False   # merged dispatches are never time-budgeted
    ctx["pipe_cache"] = {}
    ctx["variant_cache"] = {}
    return TrialCohort(
        specs=list(payload["specs"]),
        tids=[int(t) for t in payload["tids"]],
        rung_i=int(payload["rung_i"]),
        epochs=int(payload["epochs"]),
        collect=bool(payload["collect"]),
        ctx=ctx,
        rungs=tuple(payload["rungs"]),
        steps=tuple(payload["steps"]),
    )


def eval_task(payload: dict) -> list:
    """Evaluate one packed task: ``{"kind", "cohorts"}`` -> per-job
    ``(scored, positions)`` with lazy params materialized (wire-safe)."""
    from ..automl.batched import eval_rung_cohorts, eval_trial_megabatch
    cohorts = [cohort_restore(c) for c in payload["cohorts"]]
    fn = eval_rung_cohorts if payload["kind"] == "rung" else eval_trial_megabatch
    outs = fn(cohorts)
    return [(_materialize_scored(scored), list(positions))
            for scored, positions in outs]


# ---------------------------------------------------------------------------
# the worker loop
# ---------------------------------------------------------------------------


def _my_faults(worker_id: int,
               fault_events: Sequence[Tuple[int, int, str, float]],
               ) -> Dict[int, Tuple[str, float]]:
    return {int(t): (str(action), float(seconds))
            for (w, t, action, seconds) in fault_events
            if int(w) == int(worker_id)}


def apply_fault(action: Optional[Tuple[str, float]]) -> None:
    """Execute one fault action at the dequeue point (see module doc)."""
    if action is None:
        return
    what, seconds = action
    if what == "kill":
        os._exit(KILLED_EXIT_CODE)
    elif what in ("stall", "delay"):
        time.sleep(seconds)
    else:
        raise ValueError(f"unknown fault action {what!r}")


def handle_eval(task_id, worker_id: int, payload_bytes: bytes,
                attempt: int = 0) -> tuple:
    """Evaluate one queued task and build its full reply tuple.

    Shared by the real worker loop and the deterministic in-process twin
    (``transport.SimWorkerPool``), so both produce identical reply shapes
    and identical worker-side spans.  The parent dispatch-span id is
    re-derived from the wire header's trace context and the queue
    message's attempt number (``obs/trace.span_id`` is a pure hash)."""
    try:
        tctx = wire.trace_of(payload_bytes)
    except wire.WireError:
        tctx = None
    sink: list = []
    trace_id = parent = None
    if tctx:
        trace_id = tctx["trace_id"]
        parent = trace.span_id(trace_id, tctx["parent"], attempt)

    def _leg(name):
        if trace_id is None:
            return contextlib.nullcontext({})
        return trace.span(sink, trace_id, name, attempt=attempt,
                          parent_id=parent, worker=int(worker_id))

    t0 = time.perf_counter()
    try:
        with _leg("deserialize"):
            payload = wire.loads(payload_bytes)
        with _leg("eval"):
            outs = eval_task(payload)
        with _leg("serialize"):
            blob = wire.dumps(outs)
        return ("done", task_id, worker_id, blob,
                time.perf_counter() - t0, sink)
    except BaseException as e:   # noqa: BLE001 — report, keep serving
        return ("error", task_id, worker_id, repr(e),
                traceback.format_exc(), time.perf_counter() - t0, sink)


def worker_main(worker_id: int, task_q, result_q,
                fault_events: Sequence[Tuple[int, int, str, float]] = ()):
    """Entry point of one worker process (see module docstring)."""
    faults = _my_faults(worker_id, fault_events)
    result_q.put(("hello", worker_id, time.monotonic()))
    n_dequeued = 0
    while True:
        msg = task_q.get()
        if msg is None or msg[0] == "stop":
            break
        _op, task_id, payload_bytes = msg[0], msg[1], msg[2]
        attempt = int(msg[3]) if len(msg) > 3 else 0
        fault = faults.get(n_dequeued)
        n_dequeued += 1
        if fault is not None and fault[0] in ("kill", "stall"):
            apply_fault(fault)   # kill exits; stall sleeps pre-heartbeat
        result_q.put(("beat", worker_id, time.monotonic()))
        if fault is not None and fault[0] == "delay":
            apply_fault(fault)
        result_q.put(handle_eval(task_id, worker_id, payload_bytes, attempt))
