"""Versioned wire serialization for the cross-process serving tier
(DESIGN.md §14.2).

One self-describing binary format carries everything the transport ships
between the front end and its workers — ``TrialCohort`` payloads, scored
rung results, ``SearchState`` snapshots, whole-scheduler checkpoints::

    blob = dumps(obj)          # bytes
    obj2 = loads(blob)         # round-trips exactly

Layout::

    b"SBWR" | u32 version | u32 header_len | header JSON | buffer bytes...

The header is a JSON tree in which every value is either a JSON primitive
or a tagged node (``{"__a__": i}`` array buffer reference, ``{"__t__":
[...]}`` tuple, ``{"__d__": [[k, v], ...]}`` dict, ``{"__dc__": "module:
Class", ...}`` dataclass, ``{"__key__": ...}`` JAX PRNG key).  Array data
travels as raw little-endian buffers after the header, so **every** tensor —
index/int tensors included — round-trips bit-exactly (the float "tolerance"
allowed by the format contract is never actually spent by this codec; it is
reserved for future codecs that compress).

Versioning: ``loads`` rejects any payload whose version differs from
``WIRE_VERSION`` with a ``WireVersionError`` naming both versions — a
front end never silently misparses a newer worker's reply (or vice versa).
Version 2 added the optional ``trace`` header field — the cross-process
span-propagation context (``obs/trace.child_ctx``).  The bump is
deliberate even though a v1 reader could parse the buffers: a v1 endpoint
would silently *drop* the trace context and the per-job timeline would be
missing its worker legs with no error anywhere, which is exactly the
silent-misparse class the version check exists to prevent (DESIGN.md
§15.2).
Version 3 marks the addition of the experience store to scheduler
snapshot payloads (DESIGN.md §17.4).  Same rationale: a v2 reader would
parse the buffers fine but silently *drop* the accumulated cross-tenant
history, and the restored server would quietly cold-start every job —
a behavioral regression with no error anywhere.

Dataclasses are encoded by qualified name and re-imported on decode;
decoding is restricted to ``repro.*`` modules so a wire payload can only
instantiate this package's own types.  Callables (e.g. the batched
backend's lazy param thunks) are deliberately not serializable — holders
must materialize them first (``engine.search_snapshot`` does).
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import struct
from typing import Any, List, Optional, Tuple

import numpy as np

__all__ = ["WIRE_VERSION", "WireError", "WireVersionError", "dumps", "loads",
           "kind_of", "trace_of"]

MAGIC = b"SBWR"
WIRE_VERSION = 3

# dataclass decoding is restricted to this package's own modules
_DC_MODULE_PREFIX = "repro."


class WireError(ValueError):
    """Malformed or unserializable wire payload."""


class WireVersionError(WireError):
    """Payload speaks a wire version this build does not."""


def _is_jax_array(obj) -> bool:
    # deferred: keep wire importable without touching jax at module load
    import jax
    return isinstance(obj, jax.Array)


def _is_prng_key(obj) -> bool:
    import jax
    return (isinstance(obj, jax.Array)
            and jax.dtypes.issubdtype(obj.dtype, jax.dtypes.prng_key))


def _enc(obj: Any, bufs: List[np.ndarray], path: str) -> Any:
    """Encode ``obj`` into a JSON-safe node, appending array buffers."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, np.ndarray):
        bufs.append(np.ascontiguousarray(obj))
        return {"__a__": len(bufs) - 1}
    if isinstance(obj, np.generic):           # numpy scalar: keep its dtype
        # np.asarray keeps the 0-d shape (ascontiguousarray would force 1-d)
        bufs.append(np.asarray(obj))
        return {"__a__": len(bufs) - 1, "scalar": True}
    if _is_jax_array(obj):
        if _is_prng_key(obj):
            import jax
            data = np.asarray(jax.random.key_data(obj))
            bufs.append(np.ascontiguousarray(data))
            return {"__key__": len(bufs) - 1}
        bufs.append(np.ascontiguousarray(np.asarray(obj)))
        return {"__a__": len(bufs) - 1}
    if isinstance(obj, bytes):
        bufs.append(np.frombuffer(obj, dtype=np.uint8))
        return {"__b__": len(bufs) - 1}
    if isinstance(obj, tuple):
        if hasattr(obj, "_fields"):        # typed NamedTuple, by qualname
            cls = type(obj)
            if not cls.__module__.startswith(_DC_MODULE_PREFIX):
                raise WireError(
                    f"refusing to wire-encode non-repro namedtuple "
                    f"{cls.__module__}:{cls.__qualname__} at {path}")
            return {"__nt__": f"{cls.__module__}:{cls.__qualname__}",
                    "f": [_enc(v, bufs, f"{path}.{name}")
                          for name, v in zip(obj._fields, obj)]}
        return {"__t__": [_enc(v, bufs, f"{path}[{i}]")
                          for i, v in enumerate(obj)]}
    if isinstance(obj, list):
        return {"__l__": [_enc(v, bufs, f"{path}[{i}]")
                          for i, v in enumerate(obj)]}
    if isinstance(obj, dict):
        return {"__d__": [[_enc(k, bufs, f"{path}.key"),
                           _enc(v, bufs, f"{path}[{k!r}]")]
                          for k, v in obj.items()]}
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        cls = type(obj)
        if not cls.__module__.startswith(_DC_MODULE_PREFIX):
            raise WireError(
                f"refusing to wire-encode non-repro dataclass "
                f"{cls.__module__}:{cls.__qualname__} at {path}")
        fields = [[f.name, _enc(getattr(obj, f.name), bufs,
                                f"{path}.{f.name}")]
                  for f in dataclasses.fields(obj)]
        return {"__dc__": f"{cls.__module__}:{cls.__qualname__}", "f": fields}
    raise WireError(
        f"not wire-serializable at {path}: {type(obj).__module__}."
        f"{type(obj).__qualname__} (materialize callables / convert to "
        f"arrays before shipping)")


def _resolve_dataclass(tag: str):
    modname, _, qualname = tag.partition(":")
    if not modname.startswith(_DC_MODULE_PREFIX):
        raise WireError(f"wire payload names non-repro dataclass {tag!r}")
    obj = importlib.import_module(modname)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    if not dataclasses.is_dataclass(obj):
        raise WireError(f"{tag!r} is not a dataclass")
    return obj


def _dec(node: Any, bufs: List[np.ndarray]):
    if node is None or isinstance(node, (bool, int, float, str)):
        return node
    if not isinstance(node, dict):
        raise WireError(f"malformed wire node: {node!r}")
    if "__a__" in node:
        arr = bufs[node["__a__"]]
        return arr[()] if node.get("scalar") else arr
    if "__key__" in node:
        import jax
        return jax.random.wrap_key_data(
            jax.numpy.asarray(bufs[node["__key__"]]))
    if "__b__" in node:
        return bufs[node["__b__"]].tobytes()
    if "__t__" in node:
        return tuple(_dec(v, bufs) for v in node["__t__"])
    if "__nt__" in node:
        modname, _, qualname = node["__nt__"].partition(":")
        if not modname.startswith(_DC_MODULE_PREFIX):
            raise WireError(
                f"wire payload names non-repro namedtuple {node['__nt__']!r}")
        cls = importlib.import_module(modname)
        for part in qualname.split("."):
            cls = getattr(cls, part)
        return cls(*(_dec(v, bufs) for v in node["f"]))
    if "__l__" in node:
        return [_dec(v, bufs) for v in node["__l__"]]
    if "__d__" in node:
        return {_dec(k, bufs): _dec(v, bufs) for k, v in node["__d__"]}
    if "__dc__" in node:
        cls = _resolve_dataclass(node["__dc__"])
        return cls(**{name: _dec(v, bufs) for name, v in node["f"]})
    raise WireError(f"unknown wire node tags: {sorted(node)}")


def dumps(obj: Any, *, kind: str = "", trace: Optional[dict] = None) -> bytes:
    """Serialize ``obj`` to a versioned wire payload.

    ``trace`` is an optional JSON-safe span-propagation context
    (``obs/trace.child_ctx``) carried in the header — readable via
    ``trace_of`` without decoding the buffers, so a worker can parent its
    spans before paying for deserialization."""
    bufs: List[np.ndarray] = []
    tree = _enc(obj, bufs, "$")
    header = {
        "v": WIRE_VERSION,
        "kind": kind,
        "obj": tree,
        "bufs": [{"d": a.dtype.str, "s": list(a.shape)} for a in bufs],
    }
    if trace is not None:
        header["trace"] = trace
    hbytes = json.dumps(header, separators=(",", ":")).encode("utf-8")
    parts = [MAGIC, struct.pack("<II", WIRE_VERSION, len(hbytes)), hbytes]
    parts.extend(a.tobytes() for a in bufs)
    return b"".join(parts)


def _read_header(data: bytes) -> Tuple[dict, int]:
    if len(data) < 12 or data[:4] != MAGIC:
        raise WireError("not a SubStrat wire payload (bad magic)")
    version, hlen = struct.unpack_from("<II", data, 4)
    if version != WIRE_VERSION:
        raise WireVersionError(
            f"unsupported wire version {version}; this build speaks "
            f"version {WIRE_VERSION} — upgrade the older endpoint")
    if len(data) < 12 + hlen:
        raise WireError("truncated wire payload (header)")
    try:
        header = json.loads(data[12:12 + hlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"corrupt wire header: {e}") from None
    return header, 12 + hlen


def kind_of(data: bytes) -> str:
    """Peek a payload's ``kind`` tag without decoding its buffers."""
    header, _ = _read_header(data)
    return header.get("kind", "")


def trace_of(data: bytes) -> Optional[dict]:
    """Peek a payload's span-propagation context (v2 header field) without
    decoding its buffers; None when the sender attached no trace."""
    header, _ = _read_header(data)
    return header.get("trace")


def loads(data: bytes) -> Any:
    """Decode a wire payload produced by ``dumps``.

    Arrays come back as fresh writable host ``np.ndarray``s with the exact
    dtype, shape, and bytes they were encoded with."""
    header, off = _read_header(data)
    bufs: List[np.ndarray] = []
    for spec in header["bufs"]:
        dtype = np.dtype(spec["d"])
        shape = tuple(spec["s"])
        n_elem = int(np.prod(shape, dtype=np.int64))
        nbytes = dtype.itemsize * n_elem
        if off + nbytes > len(data):
            raise WireError("truncated wire payload (buffers)")
        arr = (np.frombuffer(data, dtype=dtype, count=n_elem, offset=off)
               .reshape(shape).copy())
        bufs.append(arr)
        off += nbytes
    return _dec(header["obj"], bufs)
