"""Multi-tenant SubStrat job scheduler (DESIGN.md §11.3, §12.4).

Turns the plan-based pipeline (``core/plan.py``) into a cooperative job
queue.  Every job carries a declarative ``Plan`` — legacy
``SubStratConfig`` submissions are converted on admission — and moves
through explicit resumable phases::

    factorize  ─►  dst  ─►  sub_automl  ─►  fine_tune  ─►  done
        │  cache hit │           │              ▲
        │            └► warm_wait ──────────────┤
        │  (known winner family) ───────────────┘
        └────────────────────────────────────────

A cache hit skips ``dst``; if the entry already names the sub-AutoML winner
family, the job warm-starts straight into ``fine_tune``.  If the family is
not yet known but another in-flight job on the same cache key is about to
produce it, the repeat parks in ``warm_wait`` instead of duplicating the
sub-AutoML pass (in-flight dedup) and un-parks the moment the leader
publishes its winner — falling back to running the pass itself if every
leader disappears.

``step()`` advances every active job by exactly one unit of work — one
phase transition, or one successive-halving rung of its current AutoML
search.  Work merges across jobs at two layers:

- **dst**: concurrent cache-miss jobs whose plans name the same *batchable*
  strategy (``StrategySpec.batch_fn`` — Gen-DST and its island variant) on
  same-shaped datasets run their searches in one vmapped dispatch
  (``gen_dst_batch``), bit-identical per search to solo execution.
- **sub_automl / fine_tune**: ready rung cohorts pack into one standing
  **megabatch** per step — continuous rung batching (DESIGN.md §13).  A
  cohort joins the dispatch at *any* rung: each trial carries its own rung
  cursor and epoch budget into the batched engine
  (``batched.eval_trial_megabatch``), which runs shorter trials as
  step-masked passengers of the longest scan.  Admission is governed by a
  single **waste budget**: a group is packed only while its padded compute
  (every trial priced at the group-maximal rows × features × classes ×
  steps) stays within ``waste_budget``× the useful compute
  (``merge_waste``) — one policy across row, class, *and* step padding,
  subsuming the per-axis ``hetero_pad_limit`` heuristic (deprecated).
  Same-shaped cohorts merge exactly regardless of rung (bit-identical per
  trial — §13.3); differently-shaped ones merge through maximal-shape
  padding with row/class masks (§12.3) when ``hetero_merge`` is on.
  ``megabatch=False`` restores lockstep ``(rung_i, epochs)`` bucketing.
  Merged wall time is attributed to participants in equal shares.

The DST cache keys on the plan's subset identity —
``(fingerprint, n, m, measure, (strategy, strategy_opts))`` — so *every*
registered cacheable strategy (all the paper baselines, the ASP proxy
scorer) is cached and warm-started exactly like Gen-DST.  Jobs with a bare
callable strategy (the deprecated ``dst_fn``) bypass the cache.

Beyond the exact-fingerprint cache, the scheduler meta-learns across
tenants (DESIGN.md §17): every sub-AutoML rung feeds the
``meta.ExperienceStore`` (fingerprint × trial spec → rung accuracies), and
once enough *distinct* datasets have finished (``warm_min_history``), a new
job's sub pass is seeded with the greedy submodular portfolio built from
the k-NN meta-feature slice of that history — fewer rung-0 trials, each
bit-identical to its cold-run counterpart (the portfolio filters the
deterministically sampled population, preserving trial ids).  Cold starts
and ``Plan(warm_start=False)`` jobs run the unchanged full population.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from ..automl.engine import (
    SearchState, search_eval_rung, search_init, search_record, search_restore,
    search_result, search_snapshot, search_trial_cohort,
)
from ..core.measures import CodedDataset, factorize
from ..core.plan import Plan, plan_from_config
from ..core.strategies import run_strategy, run_strategy_batch
from ..core.substrat import (
    SubStratConfig, SubStratResult, build_subset, dst_feature_columns,
    nf_test_eval,
)
from ..meta import (
    ExperienceStore, meta_features, portfolio_coverage, portfolio_for,
)
from ..obs import jaxprof, trace
from ..obs.metrics import MetricsRegistry
from .cache import DSTCache, DSTCacheEntry, dst_cache_key
from .fingerprint import dataset_fingerprint

__all__ = ["CohortMeta", "Scheduler", "SubStratJob", "PHASES",
           "merge_waste", "pack_megabatches"]

PHASES = ("factorize", "dst", "warm_wait", "sub_automl", "fine_tune",
          "done", "failed")

# times-dict key per AutoML phase (matches substrat()'s per-phase keys)
_PHASE_TIME_KEY = {"sub_automl": "automl_sub_s", "fine_tune": "fine_tune_s"}


def _plan_measure(plan: Plan) -> str:
    """The preserved measure named by a plan's strategy options (the
    ``measure`` field of a GenDSTConfig ``cfg`` option), defaulting to the
    paper's entropy measure every baseline targets."""
    for k, v in plan.strategy_opts:
        if k == "cfg" and hasattr(v, "measure"):
            return v.measure
        if k == "measure":
            return v
    return "entropy"


# ---------------------------------------------------------------------------
# megabatch packing policy (DESIGN.md §13.2) — pure, host-side, testable
# ---------------------------------------------------------------------------


class CohortMeta(NamedTuple):
    """The packing-relevant summary of one ready rung cohort."""
    shape: Tuple[int, int, int, int]   # (N_tr, N_val, d, n_classes)
    steps: Tuple[int, ...]             # per-trial epoch budgets this rung


def _padded_unit(metas: Sequence[CohortMeta]) -> float:
    """Per-trial padded cost under the group-maximal shape and scan length:
    ``(steps_max · Ntr_max + Nval_max) · d_max · c_max``.  Train cost scales
    with steps; the fused validation eval is one pass."""
    ntr = max(m.shape[0] for m in metas)
    nval = max(m.shape[1] for m in metas)
    d = max(m.shape[2] for m in metas)
    c = max(m.shape[3] for m in metas)
    smax = max(max(m.steps) for m in metas)
    return float((smax * ntr + nval) * d * c)


def merge_waste(metas: Sequence[CohortMeta]) -> float:
    """Padded-to-useful compute ratio of merging ``metas`` into one dispatch.

    Every trial in the merged dispatch costs the group-maximal padded unit;
    its useful compute is its *own* ``(steps·N_tr + N_val)·d·c``.  The ratio
    is a single waste measure across all padding axes — rows, features,
    classes, *and* scan steps — so a cohort narrow in rows but wide in
    classes (or short in steps) is priced correctly, which the old per-axis
    ``hetero_pad_limit`` check was not (it ignored classes and steps).
    A singleton uniform cohort scores exactly 1.0."""
    total = sum(len(m.steps) for m in metas) * _padded_unit(metas)
    useful = sum((st * m.shape[0] + m.shape[1]) * m.shape[2] * m.shape[3]
                 for m in metas for st in m.steps)
    return total / useful


def pack_megabatches(metas: Sequence[CohortMeta], waste_budget: float,
                     same_shape_only: bool = False) -> List[List[int]]:
    """Pack ready cohorts into megabatch groups under the waste budget.

    Deterministic first-fit-decreasing: cohorts are visited in descending
    per-cohort padded cost (stable on input order), and each joins the first
    group whose combined ``merge_waste`` stays ``<= waste_budget`` — big
    cohorts seed groups, small ones ride along only where the padding they
    would absorb is paid for by the dispatches they save.
    ``same_shape_only`` (the ``hetero_merge=False`` regime) additionally
    requires exact data-shape equality, so every group stays a bit-identical
    merge regardless of rung mix.  Returns groups of indices into ``metas``;
    every index appears in exactly one group (singletons allowed — a lone
    cohort always fits its own group)."""
    order = sorted(range(len(metas)),
                   key=lambda i: (-_padded_unit([metas[i]]), i))
    groups: List[List[int]] = []
    for i in order:
        placed = False
        for g in groups:
            if same_shape_only and metas[g[0]].shape != metas[i].shape:
                continue
            if merge_waste([metas[j] for j in g + [i]]) <= waste_budget:
                g.append(i)
                placed = True
                break
        if not placed:
            groups.append([i])
    for g in groups:
        g.sort()   # job order within a dispatch follows submission order
    return groups


@dataclasses.dataclass
class SubStratJob:
    """One submitted SubStrat run and its phase state."""
    job_id: int
    tenant: str
    X: np.ndarray
    y: np.ndarray
    key: jax.Array
    plan: Plan
    coded: Optional[CodedDataset] = None
    X_test: Optional[np.ndarray] = None
    y_test: Optional[np.ndarray] = None

    phase: str = "factorize"
    times: Dict[str, float] = dataclasses.field(default_factory=dict)
    cache_hit: bool = False
    warm_family: Optional[str] = None      # cache-known winner (skips sub pass)
    fingerprint: Optional[str] = None
    cache_key: Optional[tuple] = None
    row_idx: Optional[np.ndarray] = None
    col_mask: Optional[np.ndarray] = None
    col_idx: Optional[np.ndarray] = None
    dst_fitness: Optional[float] = None
    y_sub: Optional[np.ndarray] = None     # NF test eval needs the subset labels
    search: Optional[SearchState] = None   # current AutoML pass, rung-resumable
    intermediate: Optional[object] = None  # AutoMLResult M'
    final: Optional[object] = None         # AutoMLResult M_sub
    result: Optional[SubStratResult] = None
    error: Optional[BaseException] = None
    # streamed partial results: one entry per recorded rung (DESIGN.md §14.4)
    leaderboard: List[dict] = dataclasses.field(default_factory=list)
    # observability (DESIGN.md §15.1): deterministic per-job trace id and
    # the closed span records of every phase/rung/dispatch the job touched
    trace_id: str = ""
    spans: List[dict] = dataclasses.field(default_factory=list)

    @property
    def active(self) -> bool:
        return self.phase not in ("done", "failed")

    @property
    def cost_s(self) -> float:
        return sum(self.times.values())

    @property
    def strategy_name(self) -> str:
        s = self.plan.strategy
        return s if isinstance(s, str) else getattr(s, "__name__", "<callable>")


class Scheduler:
    """Cooperative multi-job scheduler with DST caching and rung merging."""

    def __init__(self, cache: Optional[DSTCache] = None, *,
                 warm_start: bool = True, hetero_merge: bool = True,
                 megabatch: bool = True, waste_budget: float = 4.0,
                 hetero_pad_limit: Optional[float] = None,
                 batch_dst: bool = False,
                 experience: Optional[ExperienceStore] = None,
                 warm_min_history: int = 3, portfolio_k: int = 6,
                 portfolio_knn: int = 4):
        self.cache = cache if cache is not None else DSTCache()
        self.warm_start = warm_start
        # cross-tenant meta-learning (DESIGN.md §17): served-job history and
        # the portfolio warm-start policy built from it.  warm_start=False
        # disables feeding and seeding alike (the pre-§17 scheduler).
        self.experience = (experience if experience is not None
                           else ExperienceStore())
        self.warm_min_history = warm_min_history
        self.portfolio_k = portfolio_k
        self.portfolio_knn = portfolio_knn
        self.hetero_merge = hetero_merge
        # continuous rung batching (DESIGN.md §13): one standing cross-rung
        # dispatch per step instead of lockstep (rung_i, epochs) buckets
        self.megabatch = megabatch
        if hetero_pad_limit is not None:
            warnings.warn(
                "hetero_pad_limit is deprecated: row/class/step padding is "
                "now governed by the single waste_budget policy "
                "(merge_waste <= waste_budget); the passed value is used as "
                "waste_budget", DeprecationWarning, stacklevel=2)
            waste_budget = hetero_pad_limit
        self.waste_budget = waste_budget
        # vmap same-shaped concurrent cache-miss searches (gen_dst_batch).
        # Bit-identical per search; a device-utilization play — fills
        # parallel hardware, roughly neutral-to-negative on one CPU core
        # (benchmarks hetero_merge section), hence opt-in.
        self.batch_dst = batch_dst
        self.jobs: Dict[int, SubStratJob] = {}
        self._next_id = 0
        self.merged_rungs = 0   # merged dispatches issued
        self.merged_jobs = 0    # job-rungs that rode a merged dispatch
        self.hetero_rungs = 0   # merged dispatches that needed shape padding
        self.mixed_rungs = 0    # merged dispatches spanning >1 (rung, epochs)
        self.solo_rungs = 0     # rungs evaluated per-job
        self.merged_dst = 0     # subset searches that rode a batched dispatch
        self.poisoned_packs = 0  # failed packs re-run solo to isolate blame
        self.metrics = MetricsRegistry()
        self._register_metrics()

    def _register_metrics(self) -> None:
        """Register (or re-bind, after ``load_snapshot``) the scheduler's
        metric families — get-or-create, so calling it after a state
        restore re-attaches the ``m_*`` handles to the restored families
        (DESIGN.md §15.3).  Subclasses extend, never replace."""
        m = self.metrics
        self.m_dispatches = m.counter(
            "dispatches_total", "rung dispatches by execution mode", ("mode",))
        self.m_dispatch_latency = m.histogram(
            "dispatch_latency_seconds",
            "wall seconds of one rung dispatch (merged: whole group)",
            ("mode",))
        self.m_cache_hits = m.counter(
            "cache_hits_total", "DST cache hits at job admission/re-probe")
        self.m_cache_misses = m.counter(
            "cache_misses_total", "cacheable jobs admitted without an entry")
        self.m_poisoned = m.counter(
            "poisoned_packs_total",
            "failed packed dispatches re-run solo to isolate blame")
        self.m_jobs_finished = m.counter(
            "jobs_finished_total", "jobs reaching a terminal phase",
            ("phase",))
        self.m_pack_waste = m.gauge(
            "pack_waste_ratio",
            "merge_waste (padded/useful compute) of the newest megabatch "
            "group")
        self.m_padded_flops = m.counter(
            "pack_padded_flops_total",
            "analytic FLOPs packed dispatches actually execute (padded "
            "shapes/steps)")
        self.m_useful_flops = m.counter(
            "pack_useful_flops_total",
            "analytic FLOPs the packed trials needed at their own "
            "shapes/steps")
        self.m_portfolio_hits = m.counter(
            "portfolio_hits_total",
            "sub-AutoML passes seeded from the experience-store portfolio")
        self.m_portfolio_seeded = m.counter(
            "portfolio_seeded_trials_total",
            "rung-0 trials seeded by portfolio warm-starts")
        self.m_portfolio_saved = m.counter(
            "portfolio_trials_saved_total",
            "rung-0 trials a warm-started pass skipped vs its cold "
            "population")
        self.m_portfolio_coverage = m.gauge(
            "portfolio_coverage",
            "covered-dataset best-accuracy F(P) of the newest portfolio")
        self.m_experience_datasets = m.gauge(
            "experience_datasets",
            "distinct trained fingerprints in the experience store")

    @property
    def hetero_pad_limit(self) -> float:
        """Deprecated alias of ``waste_budget`` (kept for introspection)."""
        return self.waste_budget

    # -- submission ---------------------------------------------------------

    def submit(
        self,
        X: np.ndarray,
        y: np.ndarray,
        *,
        tenant: str = "default",
        key: Optional[jax.Array] = None,
        plan: Optional[Plan] = None,
        config: Optional[SubStratConfig] = None,
        dst_fn: Optional[Callable] = None,
        coded: Optional[CodedDataset] = None,
        X_test: Optional[np.ndarray] = None,
        y_test: Optional[np.ndarray] = None,
    ) -> int:
        """Admit a job; returns its id.  No work happens until ``step()``.

        ``plan`` is the native submission payload; ``config`` (+ the
        deprecated ``dst_fn``) is converted via ``plan_from_config`` for
        legacy call sites and produces identical execution."""
        if dst_fn is not None:
            warnings.warn(
                "submit(dst_fn=...) is deprecated; pass the generator as a "
                "Plan strategy (plan(my_fn, ...)) or register it via "
                "repro.core.strategies.register_strategy",
                DeprecationWarning, stacklevel=2)
        if plan is None:
            plan = plan_from_config(config or SubStratConfig(), dst_fn)
        elif config is not None or dst_fn is not None:
            raise ValueError("pass either plan= or config=/dst_fn=, not both")
        job = SubStratJob(
            job_id=self._next_id, tenant=tenant, X=X, y=y,
            key=jax.random.key(0) if key is None else key,
            plan=plan, coded=coded, X_test=X_test, y_test=y_test,
            trace_id=trace.job_trace_id(self._next_id),
        )
        self.jobs[job.job_id] = job
        self._next_id += 1
        return job.job_id

    def pending(self) -> List[SubStratJob]:
        return [j for j in self.jobs.values() if j.active]

    # -- phase work ---------------------------------------------------------

    def _job_time_span(self, job: SubStratJob, name: str, key: str,
                       w0: float, seconds: float, **attrs) -> None:
        """Record one closed span on the job's trace AND fold its cost into
        ``job.times[key]`` — the span record is the phase-time bookkeeping
        (DESIGN.md §15.1), not a parallel ledger.  ``seconds`` may be an
        attributed equal share of a merged dispatch rather than the span's
        own wall extent; the span keeps both (extent in t0/t1, share in
        attrs)."""
        job.spans.append(trace.make_span(
            job.trace_id, name, w0, time.time(),
            attrs={"seconds": float(seconds), **attrs}))
        job.times[key] = job.times.get(key, 0.0) + float(seconds)

    def _fold_task_spans(self, group: Sequence[SubStratJob],
                         spans: Sequence[dict]) -> None:
        """Copy one remote dispatch's transport/worker spans onto every
        participating job's trace.  The copies are re-tagged with the job's
        trace id for single-timeline rendering; span/parent ids are stored
        explicitly in each record, so the dispatch→queue_wait→eval tree
        survives the re-tag intact."""
        for job in group:
            for sp in spans:
                cp = dict(sp)
                cp["trace_id"] = job.trace_id
                cp["attrs"] = dict(sp["attrs"])
                job.spans.append(cp)

    def _factorize(self, job: SubStratJob) -> None:
        t0 = time.perf_counter()
        w0 = time.time()
        if job.coded is None:
            job.coded = factorize(job.X, job.y)
        job.fingerprint = dataset_fingerprint(job.coded)
        if self.warm_start:
            # register the dataset's meta-feature vector (free: derived
            # from the codes just factorized, sharing the DST entropy trace)
            self.experience.note_meta(job.fingerprint,
                                      meta_features(job.coded))
        self._job_time_span(job, "factorize", "factorize_s", w0,
                            time.perf_counter() - t0, phase="factorize")

        # the cache key is the plan's resolved subset identity — the actual
        # search problem, not the (possibly None) plan fields
        if job.plan.cacheable:
            n, m, strategy, opts = job.plan.subset_identity(job.coded)
            job.cache_key = dst_cache_key(
                job.fingerprint, n, m, _plan_measure(job.plan),
                search_cfg=(strategy, opts))

        if not self._try_cache_hit(job):
            if job.cache_key is not None:
                self.m_cache_misses.inc()
            job.phase = "dst"

    def _try_cache_hit(self, job: SubStratJob) -> bool:
        """Probe the DST cache; on a hit, install the stored subset and
        advance the job past the subset search (and, when warm-startable,
        past the sub-AutoML pass)."""
        t0 = time.perf_counter()
        w0 = time.time()
        entry = self.cache.get(job.cache_key) if job.cache_key else None
        if entry is None:
            return False
        # cache hit: the stored subset replaces the whole strategy search;
        # gen_dst_s records what the hit actually cost (the lookup)
        job.cache_hit = True
        self.m_cache_hits.inc()
        self._install_subset(job, entry.row_idx, entry.col_mask, entry.fitness)
        self._job_time_span(job, "cache_probe", "gen_dst_s", w0,
                            time.perf_counter() - t0, cache_hit=True)
        if self.warm_start and job.plan.fine_tune and entry.winner_family:
            job.warm_family = entry.winner_family
            job.phase = "fine_tune"
        elif (self.warm_start and job.plan.fine_tune
              and self._family_leader(job) is not None):
            # a concurrent job on the same cache key is already running the
            # sub-AutoML pass: wait for its winner family instead of
            # duplicating the pass (in-flight dedup; resolves in step())
            job.phase = "warm_wait"
        else:
            job.phase = "sub_automl"
        return True

    def _install_subset(self, job: SubStratJob, row_idx, col_mask,
                        fitness) -> None:
        job.row_idx, job.col_mask = row_idx, col_mask
        job.dst_fitness = fitness
        job.col_idx = dst_feature_columns(col_mask, job.coded.target_col)

    def _family_leader(self, job: SubStratJob) -> Optional[SubStratJob]:
        """An active job on the same cache key whose sub-AutoML pass will
        publish the winner family this job could warm-start from."""
        for other in self.jobs.values():
            if (other is not job and other.active
                    and other.cache_key == job.cache_key
                    and other.phase in ("dst", "sub_automl")):
                return other
        return None

    def _advance_waiters(self) -> bool:
        """Resolve warm-wait jobs: warm-start once the family is published,
        or fall back to running the sub pass if every leader is gone."""
        worked = False
        for job in self.pending():
            if job.phase != "warm_wait":
                continue
            entry = (self.cache.peek(job.cache_key)
                     if job.cache_key is not None else None)
            if entry is not None and entry.winner_family:
                job.warm_family = entry.winner_family
                job.phase = "fine_tune"
                worked = True
            elif self._family_leader(job) is None:
                job.phase = "sub_automl"   # leader failed/evicted: run it
                worked = True
        return worked

    # -- subset search: batched where the strategy allows -------------------

    def _reprobe(self, job: SubStratJob) -> bool:
        """Re-probe the cache before searching: a same-identity job earlier
        in the queue may have inserted the entry since this job's admission
        probe (concurrent duplicate submissions coalesce onto one search);
        peek first so an absent entry doesn't count a second miss."""
        return (job.cache_key is not None
                and self.cache.peek(job.cache_key) is not None
                and self._try_cache_hit(job))

    def _record_subset(self, job: SubStratJob, subset, elapsed: float) -> None:
        self._install_subset(job, subset.row_idx, subset.col_mask,
                             subset.fitness)
        # the span's extent approximates the dispatch window (batched
        # searches hand each rep its equal share, not its own wall clock)
        self._job_time_span(job, "gen_dst", "gen_dst_s",
                            time.time() - elapsed, elapsed,
                            phase="dst", strategy=job.strategy_name)
        if job.cache_key is not None:
            self.cache.put(job.cache_key, DSTCacheEntry(
                row_idx=job.row_idx, col_mask=job.col_mask,
                fitness=job.dst_fitness, cost_s=elapsed))
        job.phase = "sub_automl"

    def _dst(self, job: SubStratJob) -> None:
        if self._reprobe(job):
            return
        p = job.plan
        t0 = time.perf_counter()
        subset = run_strategy(p.strategy, job.key, job.coded, p.n, p.m,
                              p.strategy_opts)
        self._record_subset(job, subset, time.perf_counter() - t0)

    def _dst_batch_key(self, job: SubStratJob):
        """Hashable batch-compatibility class of a job's subset search, or
        None if the search must run solo (callable strategy, no batch_fn,
        or nothing to share)."""
        p = job.plan
        if not p.batchable:
            return None
        n, m, strategy, opts = p.subset_identity(job.coded)
        return (strategy, opts, n, m, job.coded.codes.shape,
                job.coded.max_bins, job.coded.target_col)

    def _dispatch_dst(self, jobs: List[SubStratJob]) -> None:
        """Run the queue's pending subset searches: group batchable jobs by
        strategy/shape compatibility into one vmapped dispatch each
        (identical-cache-key duplicates coalesce onto one search slot),
        everything else solo."""
        groups: Dict[object, List[SubStratJob]] = {}
        solo: List[SubStratJob] = []
        for job in jobs:
            if self._reprobe(job):
                continue
            bkey = self._dst_batch_key(job) if self.batch_dst else None
            if bkey is None:
                solo.append(job)
            else:
                groups.setdefault(bkey, []).append(job)

        for job in solo:
            try:
                self._dst(job)
            except Exception as e:   # noqa: BLE001 — isolate job failures
                self._fail(job, e)

        for bkey, group in groups.items():
            # duplicate submissions (same cache key) share one search slot
            reps: List[SubStratJob] = []
            seen_keys = set()
            followers: List[SubStratJob] = []
            for job in group:
                if job.cache_key is not None and job.cache_key in seen_keys:
                    followers.append(job)
                else:
                    seen_keys.add(job.cache_key)
                    reps.append(job)
            if len(reps) == 1:
                try:
                    self._dst(reps[0])
                except Exception as e:   # noqa: BLE001
                    self._fail(reps[0], e)
            else:
                strategy, opts, n, m = bkey[0], bkey[1], bkey[2], bkey[3]
                t0 = time.perf_counter()
                try:
                    subsets = run_strategy_batch(
                        strategy, [j.key for j in reps],
                        [j.coded for j in reps], n, m, opts)
                except Exception as e:   # noqa: BLE001
                    # fail the reps only: followers fall through to the
                    # solo retry below (a batch failure, e.g. OOM on the
                    # K-wide stacked tensors, need not doom a search that
                    # would succeed solo)
                    for job in reps:
                        self._fail(job, e)
                    subsets = []
                else:
                    self.merged_dst += len(reps)
                share = (time.perf_counter() - t0) / max(len(subsets), 1)
                for job, subset in zip(reps, subsets):
                    self._record_subset(job, subset, share)
            for job in followers:   # their rep just populated the cache
                if not self._reprobe(job):
                    try:                      # rep failed / uncacheable
                        self._dst(job)
                    except Exception as e:   # noqa: BLE001
                        self._fail(job, e)

    # -- AutoML phases ------------------------------------------------------

    def _portfolio_seeds(self, job: SubStratJob):
        """The experience-store seed portfolio for a job's sub-AutoML pass,
        or None for the cold path (opted out, or not enough *other*
        datasets finished to meta-learn from)."""
        if not (self.warm_start and job.plan.warm_start
                and job.fingerprint is not None):
            return None
        store = self.experience
        exclude = {job.fingerprint}
        if store.n_trained(exclude) < self.warm_min_history:
            return None
        rec = store.records.get(job.fingerprint)
        feats = rec.features if rec is not None else None
        seeds = portfolio_for(store, feats, k=self.portfolio_k,
                              knn=self.portfolio_knn, exclude=exclude)
        if not seeds:
            return None
        self.m_portfolio_hits.inc()
        self.m_portfolio_seeded.inc(len(seeds))
        self.m_portfolio_coverage.set(
            portfolio_coverage(store.matrix(store.trained(exclude)), seeds))
        self.m_experience_datasets.set(store.n_trained())
        return seeds

    def _ensure_search(self, job: SubStratJob) -> None:
        if job.search is not None:
            return
        t0 = time.perf_counter()
        w0 = time.time()
        p = job.plan
        if job.phase == "sub_automl":
            X_sub, y_sub = build_subset(job.X, job.y, job.row_idx, job.col_idx,
                                        job.key)
            job.y_sub = y_sub
            seeds = self._portfolio_seeds(job)
            job.search = search_init(
                X_sub, y_sub, config=p.resolved_sub_automl(),
                seed_trials=seeds)
            if seeds:
                saved = len(job.search.specs) - len(job.search.alive_ids)
                if saved > 0:
                    self.m_portfolio_saved.inc(saved)
        else:   # fine_tune: restricted to M''s (or the cache-known) family
            family = job.warm_family or job.intermediate.spec.family
            job.search = search_init(
                job.X, job.y, config=p.resolved_ft_automl(),
                restrict_family=family)
        self._job_time_span(job, f"{job.phase}/init",
                            _PHASE_TIME_KEY[job.phase], w0,
                            time.perf_counter() - t0, phase=job.phase)

    def _finish_search(self, job: SubStratJob) -> None:
        if job.phase == "sub_automl":
            job.intermediate = search_result(job.search)
            job.search = None
            if job.cache_key is not None:
                self.cache.note_winner(job.cache_key,
                                       job.intermediate.spec.family)
            if self.warm_start and job.fingerprint is not None:
                # the fingerprint's history is now usable warm-start
                # material (trained() requires a winner)
                self.experience.note_winner(job.fingerprint,
                                            job.intermediate.spec)
                self.m_experience_datasets.set(self.experience.n_trained())
            if job.plan.fine_tune:
                job.phase = "fine_tune"
                return
            final = job.intermediate
            if job.X_test is not None:
                final = nf_test_eval(job.intermediate, job.y_sub, job.col_idx,
                                     job.X_test, job.y_test)
            job.final = final
        else:
            job.final = search_result(job.search, job.X_test, job.y_test)
            job.search = None
        self._complete(job)

    def _complete(self, job: SubStratJob) -> None:
        job.result = SubStratResult(
            final=job.final,
            # warm-started jobs skip the sub pass: intermediate is final
            intermediate=(job.intermediate if job.intermediate is not None
                          else job.final),
            row_idx=job.row_idx,
            col_idx=job.col_idx,
            dst_fitness=job.dst_fitness,
            times=dict(job.times),
            total_time_s=job.cost_s,
            strategy=job.strategy_name,
        )
        job.phase = "done"
        self.m_jobs_finished.inc(phase="done")
        self._release_data(job)

    def _fail(self, job: SubStratJob, error: BaseException) -> None:
        job.error, job.phase = error, "failed"
        self.m_jobs_finished.inc(phase="failed")
        self._release_data(job)

    @staticmethod
    def _release_data(job: SubStratJob) -> None:
        """Drop the finished job's dataset references: the job table is
        long-lived (poll/result/accounting) but must not pin every tenant's
        data in memory for the server's lifetime."""
        job.X = job.y = job.X_test = job.y_test = None
        job.coded = job.y_sub = job.search = None

    # -- rung dispatch: merged where compatible -----------------------------

    def _rung_key(self, job: SubStratJob):
        """Hashable ``(rung_i, epochs)`` merge bucket of a job's current
        rung, or None if the job must run solo (non-batched backend, or
        mid-rung time budget)."""
        st = job.search
        cfg = st.config
        if cfg.backend != "batched" or cfg.time_budget_s is not None:
            return None
        return (st.rung_i, int(cfg.rungs[st.rung_i]))

    def _plan_bucket(self, bucket: List[SubStratJob]):
        """Split one ``(rung_i, epochs)`` bucket into merged groups + solos
        (the lockstep ``megabatch=False`` regime).

        Same-shaped jobs merge exactly.  Differently-shaped jobs merge into
        one padded dispatch when ``hetero_merge`` is on and the bucket's
        aggregate ``merge_waste`` — one measure across row, feature, *and*
        class padding — stays within ``waste_budget``; otherwise each shape
        class merges separately."""
        cohorts = {id(job): search_trial_cohort(job.search) for job in bucket}
        by_shape: Dict[tuple, List[SubStratJob]] = {}
        for job in bucket:
            by_shape.setdefault(cohorts[id(job)].shape, []).append(job)
        if len(by_shape) > 1 and self.hetero_merge:
            metas = [CohortMeta(tc.shape, tc.trial_steps)
                     for tc in cohorts.values()]
            if merge_waste(metas) <= self.waste_budget:
                return [bucket], []
        merged, solo = [], []
        for group in by_shape.values():
            if len(group) > 1:
                merged.append(group)
            else:
                solo.append(group[0])
        return merged, solo

    def _note_rung(self, job: SubStratJob, top_k: int = 5) -> None:
        """Append a leaderboard entry for the rung just recorded — the
        streamed partial result ``poll(since=...)`` hands back rung by rung
        (DESIGN.md §14.4)."""
        st = job.search
        if st is None or not st.live:
            return
        if (job.phase == "sub_automl" and self.warm_start
                and job.fingerprint is not None):
            # feed the experience store: every scored trial of the rung just
            # recorded (rung_i already advanced past it)
            for spec, v, *_rest in st.live:
                self.experience.note_trial(job.fingerprint, spec,
                                           st.rung_i - 1, float(v))
        ranked = sorted(((float(v), i) for i, (s, v, *_) in enumerate(st.live)),
                        key=lambda t: -t[0])
        job.leaderboard.append({
            "phase": job.phase,
            "rung": st.rung_i - 1,          # rung_i already advanced past it
            "alive": len(st.alive_ids),
            "trials_done": st.n_done,
            "top": [{"family": st.live[i][0].family,
                     "preproc": st.live[i][0].preproc,
                     "feature_frac": float(st.live[i][0].feature_frac),
                     "val_acc": v}
                    for v, i in ranked[:top_k]],
        })

    def _record_group(self, group: List[SubStratJob], cohorts, outs,
                      share: float) -> None:
        """Record one successful dispatch: merge counters, per-job rung
        results, equal-share wall-time attribution, leaderboard entries."""
        if len(group) > 1:
            self.merged_rungs += 1
            self.merged_jobs += len(group)
            self.hetero_rungs += int(len({tc.shape for tc in cohorts}) > 1)
            self.mixed_rungs += int(
                len({(tc.rung_i, tc.epochs) for tc in cohorts}) > 1)
        else:
            self.solo_rungs += 1
        mode = "merged" if len(group) > 1 else "solo"
        wall = share * len(group)
        self.m_dispatches.inc(mode=mode)
        self.m_dispatch_latency.observe(wall, mode=mode)
        jaxprof.dispatch_event("rung_dispatch", wall,
                               mode=mode, jobs=len(group))
        w0 = time.time() - wall   # the dispatch window just ended
        for job, (scored, positions) in zip(group, outs):
            search_record(job.search, scored, positions, share)
            rung = job.search.rung_i - 1   # search_record advanced past it
            self._job_time_span(job, f"{job.phase}/rung{rung}",
                                _PHASE_TIME_KEY[job.phase], w0, share,
                                phase=job.phase, rung=rung, mode=mode)
            self._note_rung(job)

    def _isolate_failure(self, group: List[SubStratJob], cohorts,
                         eval_fn, error: BaseException) -> None:
        """A failed packed dispatch must not doom its innocent co-riders:
        re-run each member solo so only the job(s) that actually fail alone
        are marked failed (the rest lose one dispatch, not their search)."""
        if len(group) == 1:
            self._fail(group[0], error)
            return
        self.poisoned_packs += 1
        self.m_poisoned.inc()
        for job, tc in zip(group, cohorts):
            self._run_merged([job], [tc], eval_fn)

    def _run_merged(self, group: List[SubStratJob], cohorts, eval_fn) -> None:
        """Dispatch one packed group through ``eval_fn`` and record every
        job's rung; merged wall time is shared equally by participants."""
        t0 = time.perf_counter()
        try:
            outs = eval_fn(cohorts)
        except Exception as e:   # noqa: BLE001 — isolate job failures
            self._isolate_failure(group, cohorts, eval_fn, e)
            return
        self._record_group(group, cohorts, outs,
                           (time.perf_counter() - t0) / len(group))

    def _eval_groups(self, packed, eval_fn) -> None:
        """Execute packed rung groups — the transport hook (DESIGN.md §14.3).

        ``packed`` is ``[(jobs, cohorts), ...]``; the in-process default
        evaluates each group synchronously.  ``transport.DistributedScheduler``
        overrides this to ship groups to worker processes and fold the
        wire-decoded results back through ``_record_group``."""
        for group, cohorts in packed:
            self._run_merged(group, cohorts, eval_fn)

    def _dispatch_rungs(self, ready: List[SubStratJob]) -> None:
        from ..automl.batched import eval_rung_cohorts, eval_trial_megabatch

        mega: List[SubStratJob] = []
        buckets: Dict[object, List[SubStratJob]] = {}
        solo: List[SubStratJob] = []
        for job in ready:
            rkey = self._rung_key(job)
            if rkey is None:
                solo.append(job)
            elif self.megabatch and job.plan.continuous_batching:
                mega.append(job)
            else:
                buckets.setdefault(rkey, []).append(job)
        merged = []
        for bucket in buckets.values():
            if len(bucket) == 1:
                solo.append(bucket[0])
                continue
            groups, singles = self._plan_bucket(bucket)
            merged.extend(groups)
            solo.extend(singles)

        for job in solo:
            t0 = time.perf_counter()
            w0 = time.time()
            try:
                search_eval_rung(job.search)
            except Exception as e:   # noqa: BLE001 — isolate job failures
                self._fail(job, e)
                continue
            dt = time.perf_counter() - t0
            self.solo_rungs += 1
            self.m_dispatches.inc(mode="solo")
            self.m_dispatch_latency.observe(dt, mode="solo")
            rung = job.search.rung_i - 1
            self._job_time_span(job, f"{job.phase}/rung{rung}",
                                _PHASE_TIME_KEY[job.phase], w0, dt,
                                phase=job.phase, rung=rung, mode="solo")
            self._note_rung(job)

        if mega:
            # the standing megabatch (§13): every ready cohort, any rung,
            # packed under the waste budget; hetero_merge=False restricts
            # groups to exact shapes so every merge stays bit-identical
            cohorts = [search_trial_cohort(j.search) for j in mega]
            metas = [CohortMeta(tc.shape, tc.trial_steps) for tc in cohorts]
            groups = pack_megabatches(metas, self.waste_budget,
                                      same_shape_only=not self.hetero_merge)
            for gidx in groups:
                gmetas = [metas[i] for i in gidx]
                self.m_pack_waste.set(merge_waste(gmetas))
                padded, useful = jaxprof.pack_flops(gmetas)
                self.m_padded_flops.inc(padded)
                self.m_useful_flops.inc(useful)
            self._eval_groups(
                [([mega[i] for i in gidx], [cohorts[i] for i in gidx])
                 for gidx in groups],
                eval_trial_megabatch)

        if merged:
            self._eval_groups(
                [(group, [search_trial_cohort(j.search) for j in group])
                 for group in merged],
                eval_rung_cohorts)

    # -- the cooperative loop ----------------------------------------------

    def step(self) -> bool:
        """Advance every active job one phase unit.  Returns True iff any
        work was done (False means nothing is pending)."""
        worked = False
        dst_ready: List[SubStratJob] = []
        for job in sorted(self.pending(), key=lambda j: j.job_id):
            try:
                if job.phase == "factorize":
                    self._factorize(job)
                    worked = True
            except Exception as e:   # noqa: BLE001 — isolate job failures
                self._fail(job, e)
                worked = True
            if job.phase == "dst":
                dst_ready.append(job)
        if dst_ready:
            self._dispatch_dst(dst_ready)
            worked = True

        ready: List[SubStratJob] = []
        for job in sorted(self.pending(), key=lambda j: j.job_id):
            if job.phase not in ("sub_automl", "fine_tune"):
                continue
            try:
                self._ensure_search(job)
            except Exception as e:   # noqa: BLE001
                self._fail(job, e)
                worked = True
                continue
            ready.append(job)
        if ready:
            self._dispatch_rungs(ready)
            worked = True
            for job in ready:
                if job.active and job.search is not None and job.search.done:
                    try:
                        self._finish_search(job)
                    except Exception as e:   # noqa: BLE001
                        self._fail(job, e)
        # release warm-waiters last, so the step that publishes a winner
        # family also un-parks the jobs waiting on it
        if self._advance_waiters():
            worked = True
        return worked

    def run(self) -> None:
        """Drive all pending jobs to completion."""
        while self.pending():
            if not self.step():   # pragma: no cover — step always works
                raise RuntimeError("scheduler stalled with pending jobs")

    def stats(self) -> dict:
        phases: Dict[str, int] = {}
        for job in self.jobs.values():
            phases[job.phase] = phases.get(job.phase, 0) + 1
        return {
            "jobs": phases,
            "cache": self.cache.stats(),
            "merged_rungs": self.merged_rungs,
            "merged_jobs": self.merged_jobs,
            "hetero_rungs": self.hetero_rungs,
            "mixed_rungs": self.mixed_rungs,
            "solo_rungs": self.solo_rungs,
            "merged_dst": self.merged_dst,
            "poisoned_packs": self.poisoned_packs,
            "metrics": self.metrics.to_dict(),
        }

    # -- checkpoint / restore (DESIGN.md §14.5) ------------------------------

    _COUNTER_FIELDS = ("merged_rungs", "merged_jobs", "hetero_rungs",
                       "mixed_rungs", "solo_rungs", "merged_dst",
                       "poisoned_packs")
    _JOB_PLAIN_FIELDS = ("job_id", "tenant", "X", "y", "X_test", "y_test",
                         "phase", "cache_hit", "warm_family", "fingerprint",
                         "cache_key", "row_idx", "col_mask", "col_idx",
                         "dst_fitness", "y_sub", "intermediate", "final",
                         "result", "trace_id")

    def snapshot(self) -> bytes:
        """Serialize the whole scheduler — every job (including mid-search
        ``SearchState``s), the DST cache, and the merge counters — to one
        versioned wire payload.  A fresh scheduler that ``load_snapshot``s
        it resumes in-progress jobs bit-identically (rung-boundary
        granularity: ``step()`` snapshots land between rungs)."""
        from . import wire
        jobs = []
        for job in self.jobs.values():
            d = {f: getattr(job, f) for f in self._JOB_PLAIN_FIELDS}
            d["key"] = job.key
            d["plan"] = job.plan
            d["coded"] = job.coded
            d["times"] = dict(job.times)
            d["leaderboard"] = list(job.leaderboard)
            d["spans"] = list(job.spans)
            d["search"] = (search_snapshot(job.search)
                           if job.search is not None else None)
            d["error"] = None if job.error is None else repr(job.error)
            jobs.append(d)
        payload = {
            "jobs": jobs,
            "next_id": self._next_id,
            "counters": {k: getattr(self, k) for k in self._COUNTER_FIELDS},
            "cache": self.cache.items(),
            "metrics": self.metrics.state_dict(),
            # the experience store rides every snapshot (wire version 3) so
            # a restored server warm-starts exactly like the one that died
            "experience": self.experience.state_dict(),
        }
        return wire.dumps(payload, kind="scheduler")

    def load_snapshot(self, data: bytes) -> None:
        """Restore state captured by ``snapshot`` (replaces current state)."""
        from . import wire
        payload = wire.loads(data)
        self.jobs.clear()
        for d in payload["jobs"]:
            job = SubStratJob(
                job_id=d["job_id"], tenant=d["tenant"], X=d["X"], y=d["y"],
                key=d["key"], plan=d["plan"], coded=d["coded"],
                X_test=d["X_test"], y_test=d["y_test"])
            for f in self._JOB_PLAIN_FIELDS:
                setattr(job, f, d[f])
            job.times = dict(d["times"])
            job.leaderboard = list(d["leaderboard"])
            job.spans = list(d.get("spans", []))
            job.search = (search_restore(d["search"])
                          if d["search"] is not None else None)
            # the original exception class is gone; keep its repr visible
            job.error = (None if d["error"] is None
                         else RuntimeError(d["error"]))
            self.jobs[job.job_id] = job
        self._next_id = payload["next_id"]
        for k, v in payload["counters"].items():
            setattr(self, k, v)
        for key, entry in payload["cache"]:
            self.cache.put(key, entry)
        if "metrics" in payload:
            # restore first, then re-register: get-or-create re-attaches the
            # m_* handles to the restored families (bit-identical round trip)
            self.metrics.load_state(payload["metrics"])
            self._register_metrics()
        if "experience" in payload:
            self.experience.load_state(payload["experience"])

    def save_checkpoint_to(self, ckpt_dir, step: int, *, keep: int = 3) -> None:
        """Write ``snapshot()`` as an atomic on-disk checkpoint
        (``distributed/checkpoint.py`` manifest + COMMIT protocol)."""
        from ..distributed.checkpoint import save_checkpoint
        blob = np.frombuffer(self.snapshot(), dtype=np.uint8)
        save_checkpoint(ckpt_dir, step, {"wire": blob}, keep=keep)

    def restore_checkpoint(self, ckpt_dir) -> Optional[int]:
        """Restore the newest complete checkpoint under ``ckpt_dir``;
        returns its step, or None if no commit exists."""
        from ..distributed.checkpoint import restore_latest_untyped
        found = restore_latest_untyped(ckpt_dir)
        if found is None:
            return None
        leaves, step = found
        self.load_snapshot(leaves[0].tobytes())
        return step
