"""Multi-tenant SubStrat job scheduler (DESIGN.md §11.3).

Turns the one-shot ``substrat()`` pipeline into a cooperative job queue.
Every job moves through explicit resumable phases::

    factorize  ─►  dst  ─►  sub_automl  ─►  fine_tune  ─►  done
        │  cache hit │           │              ▲
        │            └► warm_wait ──────────────┤
        │  (known winner family) ───────────────┘
        └────────────────────────────────────────

A cache hit skips ``dst``; if the entry already names the sub-AutoML winner
family, the job warm-starts straight into ``fine_tune``.  If the family is
not yet known but another in-flight job on the same cache key is about to
produce it, the repeat parks in ``warm_wait`` instead of duplicating the
sub-AutoML pass (in-flight dedup) and un-parks the moment the leader
publishes its winner — falling back to running the pass itself if every
leader disappears.

``step()`` advances every active job by exactly one unit of work — one
phase transition, or one successive-halving rung of its current AutoML
search.  The AutoML phases run on the resumable ``SearchState`` API
(``engine.search_init``/``search_cohort``/``search_record``), which is what
makes **cross-job batching** possible: jobs whose current rungs are
compatible — batched backend, no wall-clock budget, same data shapes and
class count, same ``(rung_i, epochs)`` — are merged into one vmapped
dispatch of the batched engine (``batched.eval_rung_cohorts``) instead of
running per-job.  Merging changes dispatch granularity only; per-trial math
is identical to solo execution (parity argument: DESIGN.md §11.4), and the
merged rung's wall time is attributed to the participating jobs in equal
shares.

The DST cache keys on ``(fingerprint, n, m, measure, gen config)``: a
repeat submission
of a seen dataset skips Gen-DST entirely (phase ``dst`` is bypassed), and —
when the cache already knows the winning model family from a prior job's
sub-AutoML pass and ``warm_start`` is on — skips the sub-AutoML pass too,
jumping straight to the restricted fine-tune (its ``SubStratResult`` then
reports ``intermediate is final``).  Jobs with a custom ``dst_fn`` bypass
the cache: its entries are Gen-DST outputs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..automl.engine import (
    SearchState, search_cohort, search_eval_rung, search_init, search_record,
    search_result,
)
from ..core.gen_dst import default_dst_size
from ..core.measures import CodedDataset, factorize
from ..core.substrat import (
    SubStratConfig, SubStratResult, build_subset, dst_feature_columns,
    nf_test_eval, phase_dst,
)
from .cache import DSTCache, DSTCacheEntry, dst_cache_key
from .fingerprint import dataset_fingerprint

__all__ = ["Scheduler", "SubStratJob", "PHASES"]

PHASES = ("factorize", "dst", "warm_wait", "sub_automl", "fine_tune",
          "done", "failed")

# times-dict key per AutoML phase (matches substrat()'s per-phase keys)
_PHASE_TIME_KEY = {"sub_automl": "automl_sub_s", "fine_tune": "fine_tune_s"}


@dataclasses.dataclass
class SubStratJob:
    """One submitted SubStrat run and its phase state."""
    job_id: int
    tenant: str
    X: np.ndarray
    y: np.ndarray
    key: jax.Array
    config: SubStratConfig
    dst_fn: Optional[Callable] = None
    coded: Optional[CodedDataset] = None
    X_test: Optional[np.ndarray] = None
    y_test: Optional[np.ndarray] = None

    phase: str = "factorize"
    times: Dict[str, float] = dataclasses.field(default_factory=dict)
    cache_hit: bool = False
    warm_family: Optional[str] = None      # cache-known winner (skips sub pass)
    fingerprint: Optional[str] = None
    cache_key: Optional[tuple] = None
    row_idx: Optional[np.ndarray] = None
    col_mask: Optional[np.ndarray] = None
    col_idx: Optional[np.ndarray] = None
    dst_fitness: Optional[float] = None
    y_sub: Optional[np.ndarray] = None     # NF test eval needs the subset labels
    search: Optional[SearchState] = None   # current AutoML pass, rung-resumable
    intermediate: Optional[object] = None  # AutoMLResult M'
    final: Optional[object] = None         # AutoMLResult M_sub
    result: Optional[SubStratResult] = None
    error: Optional[BaseException] = None

    @property
    def active(self) -> bool:
        return self.phase not in ("done", "failed")

    @property
    def cost_s(self) -> float:
        return sum(self.times.values())


class Scheduler:
    """Cooperative multi-job scheduler with DST caching and rung merging."""

    def __init__(self, cache: Optional[DSTCache] = None, *, warm_start: bool = True):
        self.cache = cache if cache is not None else DSTCache()
        self.warm_start = warm_start
        self.jobs: Dict[int, SubStratJob] = {}
        self._next_id = 0
        self.merged_rungs = 0   # merged dispatches issued
        self.merged_jobs = 0    # job-rungs that rode a merged dispatch
        self.solo_rungs = 0     # rungs evaluated per-job

    # -- submission ---------------------------------------------------------

    def submit(
        self,
        X: np.ndarray,
        y: np.ndarray,
        *,
        tenant: str = "default",
        key: Optional[jax.Array] = None,
        config: SubStratConfig = SubStratConfig(),
        dst_fn: Optional[Callable] = None,
        coded: Optional[CodedDataset] = None,
        X_test: Optional[np.ndarray] = None,
        y_test: Optional[np.ndarray] = None,
    ) -> int:
        """Admit a job; returns its id.  No work happens until ``step()``."""
        job = SubStratJob(
            job_id=self._next_id, tenant=tenant, X=X, y=y,
            key=jax.random.key(0) if key is None else key,
            config=config, dst_fn=dst_fn, coded=coded,
            X_test=X_test, y_test=y_test,
        )
        self.jobs[job.job_id] = job
        self._next_id += 1
        return job.job_id

    def pending(self) -> List[SubStratJob]:
        return [j for j in self.jobs.values() if j.active]

    # -- phase work ---------------------------------------------------------

    def _factorize(self, job: SubStratJob) -> None:
        t0 = time.perf_counter()
        if job.coded is None:
            job.coded = factorize(job.X, job.y)
        job.fingerprint = dataset_fingerprint(job.coded)
        job.times["factorize_s"] = time.perf_counter() - t0

        # resolve the DST shape the same way gen_dst does, so the cache key
        # is the actual search problem, not the (possibly None) config fields
        N, M = job.coded.codes.shape
        dn, dm = default_dst_size(N, M)
        n = dn if job.config.n is None else min(job.config.n, N)
        m = dm if job.config.m is None else min(job.config.m, M)
        if job.dst_fn is None:
            gen = job.config.resolved_gen()
            job.cache_key = dst_cache_key(
                job.fingerprint, n, m, gen.measure, search_cfg=gen)

        if not self._try_cache_hit(job):
            job.phase = "dst"

    def _try_cache_hit(self, job: SubStratJob) -> bool:
        """Probe the DST cache; on a hit, install the stored subset and
        advance the job past Gen-DST (and, when warm-startable, past the
        sub-AutoML pass)."""
        t0 = time.perf_counter()
        entry = self.cache.get(job.cache_key) if job.cache_key else None
        if entry is None:
            return False
        # cache hit: the stored subset replaces the whole Gen-DST search;
        # gen_dst_s records what the hit actually cost (the lookup)
        job.cache_hit = True
        job.row_idx, job.col_mask = entry.row_idx, entry.col_mask
        job.dst_fitness = entry.fitness
        job.col_idx = dst_feature_columns(job.col_mask, job.coded.target_col)
        job.times["gen_dst_s"] = time.perf_counter() - t0
        if self.warm_start and job.config.fine_tune and entry.winner_family:
            job.warm_family = entry.winner_family
            job.phase = "fine_tune"
        elif (self.warm_start and job.config.fine_tune
              and self._family_leader(job) is not None):
            # a concurrent job on the same cache key is already running the
            # sub-AutoML pass: wait for its winner family instead of
            # duplicating the pass (in-flight dedup; resolves in step())
            job.phase = "warm_wait"
        else:
            job.phase = "sub_automl"
        return True

    def _family_leader(self, job: SubStratJob) -> Optional[SubStratJob]:
        """An active job on the same cache key whose sub-AutoML pass will
        publish the winner family this job could warm-start from."""
        for other in self.jobs.values():
            if (other is not job and other.active
                    and other.cache_key == job.cache_key
                    and other.phase in ("dst", "sub_automl")):
                return other
        return None

    def _advance_waiters(self) -> bool:
        """Resolve warm-wait jobs: warm-start once the family is published,
        or fall back to running the sub pass if every leader is gone."""
        worked = False
        for job in self.pending():
            if job.phase != "warm_wait":
                continue
            entry = (self.cache.peek(job.cache_key)
                     if job.cache_key is not None else None)
            if entry is not None and entry.winner_family:
                job.warm_family = entry.winner_family
                job.phase = "fine_tune"
                worked = True
            elif self._family_leader(job) is None:
                job.phase = "sub_automl"   # leader failed/evicted: run it
                worked = True
        return worked

    def _dst(self, job: SubStratJob) -> None:
        # re-probe before searching: a same-fingerprint job earlier in the
        # queue may have inserted the entry since this job's admission probe
        # (concurrent duplicate submissions coalesce onto one Gen-DST run);
        # peek first so an absent entry doesn't count a second miss
        if (job.cache_key is not None
                and self.cache.peek(job.cache_key) is not None
                and self._try_cache_hit(job)):
            return
        t0 = time.perf_counter()
        job.row_idx, job.col_mask, job.dst_fitness = phase_dst(
            job.key, job.coded, job.config, job.dst_fn)
        job.col_idx = dst_feature_columns(job.col_mask, job.coded.target_col)
        job.times["gen_dst_s"] = time.perf_counter() - t0
        if job.cache_key is not None:
            self.cache.put(job.cache_key, DSTCacheEntry(
                row_idx=job.row_idx, col_mask=job.col_mask,
                fitness=job.dst_fitness))
        job.phase = "sub_automl"

    def _ensure_search(self, job: SubStratJob) -> None:
        if job.search is not None:
            return
        t0 = time.perf_counter()
        if job.phase == "sub_automl":
            X_sub, y_sub = build_subset(job.X, job.y, job.row_idx, job.col_idx,
                                        job.key)
            job.y_sub = y_sub
            job.search = search_init(
                X_sub, y_sub, config=job.config.resolved_sub_automl())
        else:   # fine_tune: restricted to M''s (or the cache-known) family
            family = job.warm_family or job.intermediate.spec.family
            job.search = search_init(
                job.X, job.y, config=job.config.resolved_ft_automl(),
                restrict_family=family)
        key = _PHASE_TIME_KEY[job.phase]
        job.times[key] = job.times.get(key, 0.0) + (time.perf_counter() - t0)

    def _finish_search(self, job: SubStratJob) -> None:
        if job.phase == "sub_automl":
            job.intermediate = search_result(job.search)
            job.search = None
            if job.cache_key is not None:
                self.cache.note_winner(job.cache_key,
                                       job.intermediate.spec.family)
            if job.config.fine_tune:
                job.phase = "fine_tune"
                return
            final = job.intermediate
            if job.X_test is not None:
                final = nf_test_eval(job.intermediate, job.y_sub, job.col_idx,
                                     job.X_test, job.y_test)
            job.final = final
        else:
            job.final = search_result(job.search, job.X_test, job.y_test)
            job.search = None
        self._complete(job)

    def _complete(self, job: SubStratJob) -> None:
        job.result = SubStratResult(
            final=job.final,
            # warm-started jobs skip the sub pass: intermediate is final
            intermediate=(job.intermediate if job.intermediate is not None
                          else job.final),
            row_idx=job.row_idx,
            col_idx=job.col_idx,
            dst_fitness=job.dst_fitness,
            times=dict(job.times),
            total_time_s=job.cost_s,
        )
        job.phase = "done"
        self._release_data(job)

    def _fail(self, job: SubStratJob, error: BaseException) -> None:
        job.error, job.phase = error, "failed"
        self._release_data(job)

    @staticmethod
    def _release_data(job: SubStratJob) -> None:
        """Drop the finished job's dataset references: the job table is
        long-lived (poll/result/accounting) but must not pin every tenant's
        data in memory for the server's lifetime."""
        job.X = job.y = job.X_test = job.y_test = None
        job.coded = job.y_sub = job.search = None

    # -- rung dispatch: merged where compatible -----------------------------

    def _merge_key(self, job: SubStratJob):
        """Hashable compatibility class of a job's current rung, or None if
        the job must run solo (loop backend, or mid-rung time budget)."""
        st = job.search
        cfg = st.config
        if cfg.backend != "batched" or cfg.time_budget_s is not None:
            return None
        ctx = st.ctx
        return (ctx["X_tr"].shape, ctx["X_val"].shape, ctx["n_classes"],
                st.rung_i, int(cfg.rungs[st.rung_i]))

    def _dispatch_rungs(self, ready: List[SubStratJob]) -> None:
        from ..automl.batched import eval_rung_cohorts

        groups: Dict[object, List[SubStratJob]] = {}
        solo: List[SubStratJob] = []
        for job in ready:
            mkey = self._merge_key(job)
            if mkey is None:
                solo.append(job)
            else:
                groups.setdefault(mkey, []).append(job)
        merged = []
        for group in groups.values():
            if len(group) > 1:
                merged.append(group)
            else:
                solo.append(group[0])   # a merge group of one runs solo

        for job in solo:
            t0 = time.perf_counter()
            try:
                search_eval_rung(job.search)
            except Exception as e:   # noqa: BLE001 — isolate job failures
                self._fail(job, e)
                continue
            self.solo_rungs += 1
            key = _PHASE_TIME_KEY[job.phase]
            job.times[key] = job.times.get(key, 0.0) + (time.perf_counter() - t0)

        for group in merged:
            cohorts = [search_cohort(j.search) for j in group]
            rung_i = group[0].search.rung_i
            epochs = cohorts[0][2]
            collect = any(c[3] for c in cohorts)
            t0 = time.perf_counter()
            try:
                outs = eval_rung_cohorts(
                    [(c[0], c[1], j.search.ctx) for c, j in zip(cohorts, group)],
                    rung_i, epochs, collect)
            except Exception as e:   # noqa: BLE001
                for job in group:
                    self._fail(job, e)
                continue
            # the merged rung's wall time is shared equally by its jobs
            share = (time.perf_counter() - t0) / len(group)
            self.merged_rungs += 1
            self.merged_jobs += len(group)
            for job, (scored, positions) in zip(group, outs):
                search_record(job.search, scored, positions, share)
                key = _PHASE_TIME_KEY[job.phase]
                job.times[key] = job.times.get(key, 0.0) + share

    # -- the cooperative loop ----------------------------------------------

    def step(self) -> bool:
        """Advance every active job one phase unit.  Returns True iff any
        work was done (False means nothing is pending)."""
        worked = False
        for job in sorted(self.pending(), key=lambda j: j.job_id):
            try:
                if job.phase == "factorize":
                    self._factorize(job)
                    worked = True
                elif job.phase == "dst":
                    self._dst(job)
                    worked = True
            except Exception as e:   # noqa: BLE001 — isolate job failures
                self._fail(job, e)
                worked = True

        ready: List[SubStratJob] = []
        for job in sorted(self.pending(), key=lambda j: j.job_id):
            if job.phase not in ("sub_automl", "fine_tune"):
                continue
            try:
                self._ensure_search(job)
            except Exception as e:   # noqa: BLE001
                self._fail(job, e)
                worked = True
                continue
            ready.append(job)
        if ready:
            self._dispatch_rungs(ready)
            worked = True
            for job in ready:
                if job.active and job.search is not None and job.search.done:
                    try:
                        self._finish_search(job)
                    except Exception as e:   # noqa: BLE001
                        self._fail(job, e)
        # release warm-waiters last, so the step that publishes a winner
        # family also un-parks the jobs waiting on it
        if self._advance_waiters():
            worked = True
        return worked

    def run(self) -> None:
        """Drive all pending jobs to completion."""
        while self.pending():
            if not self.step():   # pragma: no cover — step always works
                raise RuntimeError("scheduler stalled with pending jobs")

    def stats(self) -> dict:
        phases: Dict[str, int] = {}
        for job in self.jobs.values():
            phases[job.phase] = phases.get(job.phase, 0) + 1
        return {
            "jobs": phases,
            "cache": self.cache.stats(),
            "merged_rungs": self.merged_rungs,
            "merged_jobs": self.merged_jobs,
            "solo_rungs": self.solo_rungs,
        }
