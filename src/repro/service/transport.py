"""Cross-process serving transport (DESIGN.md §14).

Three layers turn the in-process scheduler into a served, crash-tolerant
tier, all stdlib-only:

1. **Worker pools.**  ``ProcessWorkerPool`` spawns one ``worker.worker_main``
   subprocess per worker (``multiprocessing`` "spawn" — XLA runtime state
   must never cross a fork), each with its own task queue and one shared
   result queue.  ``SimWorkerPool`` is a drop-in in-process stand-in with the
   same five-call surface whose "workers" evaluate tasks synchronously
   through the *same* ``worker.eval_task`` code path, applying
   ``fault_events`` at the same dequeue points — so every recovery path is
   exercised deterministically, with no subprocess and (for kills) no
   timers.  ``tests/harness/faultsim.py`` builds the fault plans.

2. **Distributed scheduling.**  ``DistributedScheduler`` overrides the
   scheduler's ``_eval_groups`` transport hook: packed rung groups are
   wire-encoded (``service/wire.py``), spread over the pool with the
   deterministic ``distributed/fault.assign_shards`` placement, and the
   results folded back through ``_record_group`` — so everything above the
   hook (phases, caching, merging, budgets) is byte-for-byte the in-process
   scheduler.  Recovery state machine (§14.5):

   - a worker is declared **lost** when its process is dead, or a task has
     sat on it past ``stall_timeout_s`` with no heartbeat since dispatch
     (workers beat at task pickup, so long evaluations don't false-positive);
   - a lost worker's pending tasks re-dispatch to the survivors via
     ``assign_shards`` on the reduced alive set — deterministic given the
     fault point, so recovery runs are reproducible;
   - duplicate results (a straggler finishing after re-dispatch) resolve
     first-result-wins; evaluation is deterministic per task, so either copy
     is the same bytes;
   - with **no** survivors the front end evaluates the remainder locally —
     it is the worker of last resort, jobs always finish.

   ``ckpt_dir`` arms per-step checkpointing: scheduler snapshots (wire blob
   in a ``distributed/checkpoint.py`` manifest+COMMIT directory) that a
   restarted front end ``resume()``s bit-identically at rung granularity.

3. **HTTP front end.**  ``SubStratHTTPServer`` puts ``http.server`` in front
   of a ``SubStratServer``: wire-encoded submissions, JSON polling with
   streamed rung-by-rung leaderboards (``since`` cursor), wire-encoded
   results, and a single driver thread stepping the scheduler under a lock.
   ``SubStratHTTPClient`` is the stdlib-``urllib`` counterpart.
"""
from __future__ import annotations

import dataclasses
import json
import math
import queue
import threading
import time
import urllib.error
import urllib.parse
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..distributed.fault import Heartbeat, assign_shards
from ..obs import jaxprof, trace
from . import wire
from .scheduler import Scheduler
from .server import RateLimited, SubStratServer
from .worker import cohort_payload, eval_task, handle_eval, worker_main

__all__ = ["DistributedScheduler", "ProcessWorkerPool", "RemoteEvalError",
           "SimWorkerPool", "SubStratHTTPClient", "SubStratHTTPServer"]


class RemoteEvalError(RuntimeError):
    """A worker reported an evaluation exception for a shipped task."""


# ---------------------------------------------------------------------------
# worker pools
# ---------------------------------------------------------------------------


class ProcessWorkerPool:
    """``n_workers`` subprocesses running ``worker.worker_main``.

    One task queue per worker plus one shared result queue; ``__init__``
    blocks until every worker says hello, so interpreter/jax boot time is
    never mistaken for a stall by the scheduler's timeout."""

    def __init__(self, n_workers: int, *,
                 fault_events: Sequence[Tuple[int, int, str, float]] = (),
                 start_method: str = "spawn",
                 ready_timeout_s: float = 300.0):
        import multiprocessing as mp
        if n_workers < 1:
            raise ValueError("need at least one worker")
        ctx = mp.get_context(start_method)
        self.n_workers = n_workers
        self.result_q = ctx.Queue()
        self._task_qs = {}
        self._procs = {}
        self._dead = set()
        for w in range(n_workers):
            q = ctx.Queue()
            p = ctx.Process(target=worker_main,
                            args=(w, q, self.result_q, tuple(fault_events)),
                            daemon=True)
            p.start()
            self._task_qs[w] = q
            self._procs[w] = p
        ready = set()
        deadline = time.monotonic() + ready_timeout_s
        while len(ready) < n_workers:
            missing = sorted(set(range(n_workers)) - ready)
            dead = [w for w in missing if not self._procs[w].is_alive()]
            if dead or time.monotonic() > deadline:
                self.close()
                raise RuntimeError(
                    f"workers {dead or missing} "
                    f"{'died at boot' if dead else 'not ready'} "
                    f"(waited {ready_timeout_s}s max)")
            try:
                msg = self.result_q.get(timeout=0.5)
            except queue.Empty:
                continue
            if msg[0] == "hello":
                ready.add(msg[1])

    def send(self, worker_id: int, msg) -> None:
        self._task_qs[worker_id].put(msg)

    def recv(self, timeout_s: float):
        """Next worker message, or None after ``timeout_s``."""
        try:
            return self.result_q.get(timeout=max(timeout_s, 1e-3))
        except queue.Empty:
            return None

    def alive_workers(self) -> List[int]:
        return sorted(w for w, p in self._procs.items()
                      if w not in self._dead and p.is_alive())

    def kill(self, worker_id: int) -> None:
        """Mark a worker lost and make it so (idempotent)."""
        self._dead.add(worker_id)
        p = self._procs[worker_id]
        if p.is_alive():
            p.terminate()
        p.join(timeout=5)

    def close(self) -> None:
        for w in self.alive_workers():
            try:
                self._task_qs[w].put(("stop",))
            except (OSError, ValueError):   # pragma: no cover — closing race
                pass
        for w, p in self._procs.items():
            p.join(timeout=10)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5)
        for q in (*self._task_qs.values(), self.result_q):
            q.cancel_join_thread()
            q.close()


class SimWorkerPool:
    """Deterministic in-process stand-in for ``ProcessWorkerPool``.

    Same five-call surface, but workers are virtual: ``recv`` evaluates the
    oldest queued task of the lowest-id live worker synchronously through
    ``worker.eval_task`` — the exact code a real worker runs — and returns
    its messages one at a time.  Fault events fire at the same dequeue
    point as in ``worker.worker_main``:

    - ``kill``  — the worker dies mid-task: the task is swallowed with no
      reply and the worker drops out of ``alive_workers()`` (no clock);
    - ``stall`` — the worker stays *in* ``alive_workers()`` but never beats
      or replies again, so only the scheduler's no-beat timeout can catch
      it (use a small ``stall_timeout_s`` in tests);
    - ``delay`` — no-op in sim time: the task just runs.
    """

    def __init__(self, n_workers: int, *,
                 fault_events: Sequence[Tuple[int, int, str, float]] = ()):
        self.n_workers = n_workers
        self._inbox: Dict[int, list] = {w: [] for w in range(n_workers)}
        self._out: list = []
        self._dead = set()
        self._stalled = set()
        self._n_dequeued = {w: 0 for w in range(n_workers)}
        self._faults = {(int(w), int(t)): (str(a), float(s))
                        for (w, t, a, s) in fault_events}
        self.tasks_evaluated = 0

    def send(self, worker_id: int, msg) -> None:
        if worker_id in self._dead:
            return          # queueing to a corpse: silently lost, like mp
        self._inbox[worker_id].append(msg)

    def recv(self, timeout_s: float = 0.0):
        if self._out:
            return self._out.pop(0)
        for w in sorted(self._inbox):
            if w in self._dead or w in self._stalled or not self._inbox[w]:
                continue
            msg = self._inbox[w].pop(0)
            if msg is None or msg[0] == "stop":
                continue
            _op, task_id, payload_bytes = msg[0], msg[1], msg[2]
            attempt = int(msg[3]) if len(msg) > 3 else 0
            fault = self._faults.get((w, self._n_dequeued[w]))
            self._n_dequeued[w] += 1
            if fault is not None:
                action = fault[0]
                if action == "kill":
                    self._dead.add(w)       # task swallowed, no reply
                    return None
                if action == "stall":
                    self._stalled.add(w)    # alive but silent forever
                    return None
            self._out.append(("beat", w, time.monotonic()))
            # handle_eval is the real worker's reply builder — same tuple
            # shape, same worker-side spans, same blame-isolation semantics
            self._out.append(handle_eval(task_id, w, payload_bytes, attempt))
            self.tasks_evaluated += 1
            return self._out.pop(0)
        return None

    def alive_workers(self) -> List[int]:
        # stalled workers LOOK alive — that is the failure mode under test
        return sorted(w for w in self._inbox if w not in self._dead)

    def kill(self, worker_id: int) -> None:
        self._dead.add(worker_id)
        self._stalled.discard(worker_id)

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# the distributed scheduler
# ---------------------------------------------------------------------------


class DistributedScheduler(Scheduler):
    """Scheduler whose packed rung dispatches run on a worker pool.

    Only the ``_eval_groups`` transport hook changes; every layer above it
    (phases, DST cache, megabatch packing, budget accounting) is the
    in-process ``Scheduler`` verbatim, and per-task evaluation is a pure
    function of the shipped cohorts — which is why re-dispatching a dead
    worker's tasks to survivors reproduces the fault-free results exactly.
    """

    def __init__(self, pool, *, stall_timeout_s: float = 60.0,
                 poll_s: float = 0.02, ckpt_dir=None, ckpt_every: int = 1,
                 ckpt_keep: int = 3, **kwargs):
        super().__init__(**kwargs)
        self.pool = pool
        self.heartbeat = Heartbeat(pool.n_workers)
        self.stall_timeout_s = stall_timeout_s
        self.poll_s = poll_s
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt_keep = ckpt_keep
        self._step_no = 0
        self._task_seq = 0    # dispatch sequence: deterministic task traces
        # transport counters (surface in stats())
        self.remote_tasks = 0
        self.redispatched_tasks = 0
        self.worker_failures = 0
        self.local_fallbacks = 0
        self.dup_results = 0

    def _register_metrics(self) -> None:
        super()._register_metrics()
        m = self.metrics
        self.m_remote_tasks = m.counter(
            "remote_tasks_total", "packed tasks shipped to the worker pool")
        self.m_redispatched = m.counter(
            "redispatched_tasks_total",
            "tasks re-dispatched after their owner was declared lost")
        self.m_heartbeat_misses = m.counter(
            "heartbeat_misses_total",
            "owners declared lost (dead process, or dispatched with no "
            "heartbeat inside stall_timeout_s)")
        self.m_worker_failures = m.counter(
            "worker_failures_total", "workers removed from the alive set")
        self.m_local_fallbacks = m.counter(
            "local_fallbacks_total",
            "tasks the front end evaluated itself (no surviving workers)")
        self.m_dup_results = m.counter(
            "dup_results_total",
            "straggler results arriving after their task was re-dispatched")

    # -- transport hook ------------------------------------------------------

    def _eval_groups(self, packed, eval_fn) -> None:
        if not packed:
            return
        kind = ("rung" if getattr(eval_fn, "__name__", "")
                == "eval_rung_cohorts" else "mega")
        task_traces: Dict[int, str] = {}
        payloads: Dict[int, bytes] = {}
        for tid, (group, cohorts) in enumerate(packed):
            # deterministic per-dispatch trace; the wire header carries just
            # enough for the worker to re-derive its parent span id
            ttrace = trace.span_id("substrat-tasks", str(self._task_seq))
            self._task_seq += 1
            task_traces[tid] = ttrace
            payloads[tid] = wire.dumps(
                {"kind": kind,
                 "cohorts": [cohort_payload(tc) for tc in cohorts]},
                kind="task", trace=trace.child_ctx(ttrace, "dispatch"))
        results = self._run_remote(payloads,
                                   {tid: len(g) for tid, (g, _) in
                                    enumerate(packed)},
                                   task_traces)
        for tid, (group, cohorts) in enumerate(packed):
            status, val, share, spans = results[tid]
            self._fold_task_spans(group, spans)
            if status == "ok":
                self._record_group(group, cohorts, val, share)
            else:
                # remote failure: same blame isolation as in-process (a
                # poison job must not doom its co-riders); the solo retries
                # run locally through eval_fn
                self._isolate_failure(group, cohorts, eval_fn, val)

    def _eval_local(self, payload_bytes: bytes, group_size: int):
        t0 = time.perf_counter()
        try:
            outs = eval_task(wire.loads(payload_bytes))
        except Exception as e:   # noqa: BLE001 — blame isolation upstream
            return ("exc", e, 0.0)
        return ("ok", outs, (time.perf_counter() - t0) / group_size)

    def _run_remote(self, payloads: Dict[int, bytes],
                    group_sizes: Dict[int, int],
                    task_traces: Optional[Dict[int, str]] = None,
                    ) -> Dict[int, tuple]:
        """Dispatch wire payloads across the pool; collect with recovery.

        Returns ``{task_id: ("ok", outs, share, spans) |
        ("exc", error, 0.0, spans)}``.  ``spans`` is the task's stitched
        timeline: one dispatch span per attempt (a re-dispatch after a lost
        owner appears as a distinct retry span), each with a front-end
        queue_wait child and — for the attempt that completed — the
        worker-attached deserialize/eval/serialize children (DESIGN.md
        §15.2)."""
        task_traces = task_traces or {}
        n_tasks = len(payloads)
        results: Dict[int, tuple] = {}
        spans: Dict[int, list] = {tid: [] for tid in payloads}
        attempts: Dict[int, int] = {tid: 0 for tid in payloads}
        open_d: Dict[int, dict] = {}   # tid -> open dispatch span
        open_q: Dict[int, dict] = {}   # tid -> open queue_wait child
        pending = set(payloads)
        owner: Dict[int, int] = {}
        dispatched_at: Dict[int, float] = {}
        last_beat: Dict[int, float] = {}
        self.remote_tasks += n_tasks
        self.m_remote_tasks.inc(n_tasks)

        def _open_dispatch(tid, w):
            tt = task_traces.get(tid)
            if tt is None:
                return
            now_w = time.time()
            a = attempts[tid]
            d = trace.make_span(tt, "dispatch", now_w, now_w, attempt=a,
                                attrs={"worker": int(w)})
            q = trace.make_span(tt, "queue_wait", now_w, now_w, attempt=a,
                                parent_id=d["span_id"],
                                attrs={"worker": int(w)})
            open_d[tid], open_q[tid] = d, q

        def _note_beat(w):
            # a beat fires at task pickup: close the queue_wait of the
            # earliest-dispatched task still waiting on this worker
            waiting = [tid for tid in pending
                       if owner.get(tid) == w and tid in open_q]
            if waiting:
                tid = min(waiting, key=lambda t: dispatched_at[t])
                q = open_q.pop(tid)
                q["t1"] = time.time()
                spans[tid].append(q)

        def _close_dispatch(tid, outcome):
            now_w = time.time()
            q = open_q.pop(tid, None)
            if q is not None:       # never picked up: waited the whole time
                q["t1"] = now_w
                q["attrs"]["outcome"] = outcome
                spans[tid].append(q)
            d = open_d.pop(tid, None)
            if d is not None:
                d["t1"] = now_w
                d["attrs"]["outcome"] = outcome
                spans[tid].append(d)

        def _dispatch(tids, alive):
            amap = assign_shards(n_tasks, list(alive), self.pool.n_workers)
            now = time.monotonic()
            for tid in sorted(tids):
                w = amap[tid]
                owner[tid] = w
                dispatched_at[tid] = now
                self.pool.send(w, ("eval", tid, payloads[tid], attempts[tid]))
                _open_dispatch(tid, w)

        def _fall_back_locally(tids):
            self.local_fallbacks += len(tids)
            self.m_local_fallbacks.inc(len(tids))
            for tid in sorted(tids):
                _close_dispatch(tid, "lost")
                w0 = time.time()
                status, val, share = self._eval_local(payloads[tid],
                                                      group_sizes[tid])
                tt = task_traces.get(tid)
                if tt is not None:
                    spans[tid].append(trace.make_span(
                        tt, "local_fallback", w0, time.time(),
                        attempt=attempts[tid], attrs={"outcome": status}))
                results[tid] = (status, val, share)
                pending.discard(tid)

        alive = self.pool.alive_workers()
        if not alive:
            _fall_back_locally(set(pending))
            return {tid: (*r, spans[tid]) for tid, r in results.items()}
        _dispatch(pending, alive)

        while pending:
            msg = self.pool.recv(self.poll_s)
            if msg is not None:
                op = msg[0]
                if op in ("hello", "beat"):
                    w = msg[1]
                    last_beat[w] = time.monotonic()
                    self.heartbeat.last_seen[w] = last_beat[w]
                    if op == "beat":
                        _note_beat(w)
                elif op in ("done", "error"):
                    # explicit per-op indices: replies now end with the
                    # worker's span list, so msg[-1] is no longer dt
                    if op == "done":
                        tid, w, dt = msg[1], msg[2], msg[4]
                        wspans = msg[5] if len(msg) > 5 else []
                    else:
                        tid, w, dt = msg[1], msg[2], msg[5]
                        wspans = msg[6] if len(msg) > 6 else []
                    self.heartbeat.beat(w, dt)
                    last_beat[w] = time.monotonic()
                    if tid not in pending:
                        self.dup_results += 1   # straggler after re-dispatch
                        self.m_dup_results.inc()
                        continue
                    spans[tid].extend(wspans)
                    _close_dispatch(tid, "ok" if op == "done" else "error")
                    self.m_dispatches.inc(mode="remote")
                    self.m_dispatch_latency.observe(dt, mode="remote")
                    jaxprof.dispatch_event("remote_dispatch", dt,
                                           worker=int(w),
                                           attempt=attempts[tid])
                    if op == "done":
                        outs = wire.loads(msg[3])
                        results[tid] = ("ok", outs, dt / group_sizes[tid])
                    else:
                        results[tid] = ("exc", RemoteEvalError(
                            f"worker {w}: {msg[3]}\n{msg[4]}"), 0.0)
                    pending.discard(tid)
                continue   # drain the queue before running failure checks

            # no message this tick: look for dead or stalled owners
            now = time.monotonic()
            alive_now = set(self.pool.alive_workers())
            lost = set()
            for tid in pending:
                w = owner[tid]
                if w not in alive_now:
                    lost.add(w)
                elif (now - dispatched_at[tid] > self.stall_timeout_s
                      and last_beat.get(w, -1.0) < dispatched_at[tid]):
                    lost.add(w)   # dispatched, never beat: stalled
            if not lost:
                continue
            for w in lost:
                self.pool.kill(w)
            self.worker_failures += len(lost)
            self.m_worker_failures.inc(len(lost))
            self.m_heartbeat_misses.inc(len(lost))
            orphans = {tid for tid in pending if owner[tid] in lost}
            for tid in sorted(orphans):
                _close_dispatch(tid, "lost")
                attempts[tid] += 1   # the next dispatch is a visible retry
            survivors = self.pool.alive_workers()
            if survivors:
                self.redispatched_tasks += len(orphans)
                self.m_redispatched.inc(len(orphans))
                _dispatch(orphans, survivors)
            else:
                _fall_back_locally(orphans)
        return {tid: (*r, spans[tid]) for tid, r in results.items()}

    # -- checkpointed stepping ----------------------------------------------

    def step(self) -> bool:
        worked = super().step()
        self._step_no += 1
        if (worked and self.ckpt_dir is not None
                and self._step_no % self.ckpt_every == 0):
            self.save_checkpoint_to(self.ckpt_dir, self._step_no,
                                    keep=self.ckpt_keep)
        return worked

    def resume(self) -> Optional[int]:
        """Restore the newest complete checkpoint from ``ckpt_dir`` (a
        restarted front end picks up mid-flight jobs at the last recorded
        rung boundary).  Returns the restored step, or None."""
        if self.ckpt_dir is None:
            return None
        step = self.restore_checkpoint(self.ckpt_dir)
        if step is not None:
            self._step_no = step
        return step

    def close(self) -> None:
        self.pool.close()

    def stats(self) -> dict:
        out = super().stats()
        out["transport"] = {
            "workers_alive": len(self.pool.alive_workers()),
            "workers_total": self.pool.n_workers,
            "remote_tasks": self.remote_tasks,
            "redispatched_tasks": self.redispatched_tasks,
            "worker_failures": self.worker_failures,
            "local_fallbacks": self.local_fallbacks,
            "dup_results": self.dup_results,
        }
        return out


# ---------------------------------------------------------------------------
# HTTP front end (stdlib http.server / urllib)
# ---------------------------------------------------------------------------


def _send_json(handler, code: int, obj,
               headers: Optional[Dict[str, str]] = None) -> None:
    body = json.dumps(obj).encode("utf-8")
    handler.send_response(code)
    handler.send_header("Content-Type", "application/json")
    handler.send_header("Content-Length", str(len(body)))
    for name, value in (headers or {}).items():
        handler.send_header(name, value)
    handler.end_headers()
    handler.wfile.write(body)


def _send_wire(handler, code: int, blob: bytes) -> None:
    handler.send_response(code)
    handler.send_header("Content-Type", "application/x-substrat-wire")
    handler.send_header("Content-Length", str(len(blob)))
    handler.end_headers()
    handler.wfile.write(blob)


def _send_text(handler, code: int, text: str, content_type: str) -> None:
    body = text.encode("utf-8")
    handler.send_response(code)
    handler.send_header("Content-Type", content_type)
    handler.send_header("Content-Length", str(len(body)))
    handler.end_headers()
    handler.wfile.write(body)


class SubStratHTTPServer:
    """HTTP transport in front of a ``SubStratServer`` (DESIGN.md §14.6).

    Endpoints (all state touched under one lock; a single driver thread
    steps the scheduler whenever jobs are pending):

    - ``POST /v1/submit`` — wire payload ``{"X", "y", "tenant", "key",
      "plan", "X_test", "y_test"}`` → ``{"job_id": N}``; ``429`` with a
      ``Retry-After`` header when the tenant's token bucket is empty
    - ``GET /v1/poll?job_id=N&since=K`` — JSON ``JobStatus`` including the
      leaderboard entries from index ``K`` (streamed partial results)
    - ``GET /v1/result?job_id=N`` — wire ``SubStratResult``; ``202`` while
      the job is still running, ``500`` with the error if it failed
    - ``GET /v1/stats`` — JSON scheduler + tenant statistics
    - ``GET /v1/metrics`` — Prometheus text exposition (scheduler registry
      + process-global jit/XLA counters; DESIGN.md §15.3)
    - ``GET /v1/trace?job_id=N`` — JSON span records of one job's timeline
    """

    def __init__(self, server: SubStratServer, host: str = "127.0.0.1",
                 port: int = 0, admission_grace_s: float = 0.25):
        self.server = server
        # one scheduler step can be long (first-compile, remote dispatch), and
        # it runs under this lock — the grace window lets a client land its
        # whole batch of submissions before the driver starts stepping, so
        # co-submitted jobs merge instead of queueing behind the first step
        self.admission_grace_s = admission_grace_s
        self._last_submit = 0.0
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, *args):   # noqa: D102 — quiet by design
                pass

            def do_GET(self):
                outer._route(self, "GET")

            def do_POST(self):
                outer._route(self, "POST")

        self.httpd = ThreadingHTTPServer((host, port), _Handler)
        self.host, self.port = self.httpd.server_address[:2]
        self._threads: List[threading.Thread] = []

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "SubStratHTTPServer":
        for target in (self.httpd.serve_forever, self._drive):
            t = threading.Thread(target=target, daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def _drive(self) -> None:
        while not self._stop.is_set():
            if time.monotonic() - self._last_submit < self.admission_grace_s:
                time.sleep(self.admission_grace_s / 5)
                continue
            with self._lock:
                worked = (self.server.scheduler.step()
                          if self.server.scheduler.pending() else False)
            if not worked:
                self._wake.wait(timeout=0.05)
                self._wake.clear()

    def close(self) -> None:
        self._stop.set()
        self._wake.set()
        self.httpd.shutdown()
        self.httpd.server_close()
        for t in self._threads:
            t.join(timeout=10)

    # -- routing -------------------------------------------------------------

    def _route(self, handler, method: str) -> None:
        try:
            parsed = urllib.parse.urlsplit(handler.path)
            qs = dict(urllib.parse.parse_qsl(parsed.query))
            route = (method, parsed.path)
            if route == ("POST", "/v1/submit"):
                length = int(handler.headers.get("Content-Length", 0))
                req = wire.loads(handler.rfile.read(length))
                self._last_submit = time.monotonic()
                try:
                    with self._lock:
                        job_id = self.server.submit(
                            req["X"], req["y"],
                            tenant=req.get("tenant") or "default",
                            key=req.get("key"), plan=req.get("plan"),
                            X_test=req.get("X_test"), y_test=req.get("y_test"))
                except RateLimited as e:
                    _send_json(
                        handler, 429,
                        {"error": str(e), "retry_after_s": e.retry_after_s},
                        headers={"Retry-After":
                                 str(max(1, math.ceil(e.retry_after_s)))})
                    return
                self._last_submit = time.monotonic()
                self._wake.set()
                _send_json(handler, 200, {"job_id": job_id})
            elif route == ("GET", "/v1/poll"):
                job_id = int(qs["job_id"])
                since = int(qs.get("since", 0))
                with self._lock:
                    status = self.server.poll(job_id, since=since)
                _send_json(handler, 200, dataclasses.asdict(status))
            elif route == ("GET", "/v1/result"):
                job_id = int(qs["job_id"])
                with self._lock:
                    job = self.server.scheduler.jobs.get(job_id)
                    if job is None:
                        _send_json(handler, 404,
                                   {"error": f"unknown job {job_id}"})
                    elif job.phase == "failed":
                        _send_json(handler, 500, {"error": repr(job.error)})
                    elif job.active:
                        _send_json(handler, 202, {"phase": job.phase})
                    else:
                        _send_wire(handler, 200,
                                   wire.dumps(job.result, kind="result"))
            elif route == ("GET", "/v1/stats"):
                with self._lock:
                    stats = self.server.stats()
                _send_json(handler, 200, stats)
            elif route == ("GET", "/v1/metrics"):
                with self._lock:
                    text = self.server.metrics_text()
                _send_text(handler, 200, text,
                           "text/plain; version=0.0.4; charset=utf-8")
            elif route == ("GET", "/v1/trace"):
                job_id = int(qs["job_id"])
                with self._lock:
                    payload = self.server.trace(job_id)
                if payload is None:
                    _send_json(handler, 404,
                               {"error": f"unknown job {job_id}"})
                else:
                    _send_json(handler, 200, payload)
            else:
                _send_json(handler, 404,
                           {"error": f"no route {method} {parsed.path}"})
        except wire.WireVersionError as e:
            _send_json(handler, 426, {"error": str(e)})   # upgrade required
        except (BrokenPipeError, ConnectionResetError):   # pragma: no cover
            pass
        except Exception as e:   # noqa: BLE001 — surface, don't crash serve
            try:
                _send_json(handler, 500, {"error": repr(e)})
            except (BrokenPipeError, ConnectionResetError):  # pragma: no cover
                pass


class SubStratHTTPClient:
    """Stdlib (urllib) client for ``SubStratHTTPServer``."""

    def __init__(self, url: str, timeout_s: float = 600.0):
        # generous default: any request can queue behind one full scheduler
        # step (first-compile steps run tens of seconds) before it is served
        self.url = url.rstrip("/")
        self.timeout_s = timeout_s

    def _request(self, path: str, data: Optional[bytes] = None):
        req = urllib.request.Request(
            self.url + path, data=data,
            headers=({"Content-Type": "application/x-substrat-wire"}
                     if data is not None else {}))
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as resp:
                return resp.status, resp.read()
        except urllib.error.HTTPError as e:
            return e.code, e.read()

    @staticmethod
    def _json(body: bytes) -> dict:
        return json.loads(body.decode("utf-8"))

    def submit(self, X, y, *, tenant: str = "default", key=None, plan=None,
               X_test=None, y_test=None) -> int:
        payload = wire.dumps({
            "X": np.asarray(X), "y": np.asarray(y), "tenant": tenant,
            "key": key, "plan": plan,
            "X_test": None if X_test is None else np.asarray(X_test),
            "y_test": None if y_test is None else np.asarray(y_test),
        }, kind="submit")
        status, body = self._request("/v1/submit", data=payload)
        if status != 200:
            raise RuntimeError(f"submit failed ({status}): {body!r}")
        return self._json(body)["job_id"]

    def poll(self, job_id: int, since: int = 0) -> dict:
        status, body = self._request(
            f"/v1/poll?job_id={job_id}&since={since}")
        if status != 200:
            raise RuntimeError(f"poll failed ({status}): {body!r}")
        return self._json(body)

    def stream_leaderboard(self, job_id: int, poll_s: float = 0.05,
                           timeout_s: float = 600.0):
        """Yield each rung's leaderboard entry exactly once, until the job
        finishes (streamed partial results over plain polling)."""
        since = 0
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            st = self.poll(job_id, since=since)
            for entry in st["leaderboard"]:
                yield entry
            since = st["leaderboard_total"]
            if st["phase"] in ("done", "failed"):
                return
            time.sleep(poll_s)
        raise TimeoutError(f"job {job_id} still active after {timeout_s}s")

    def result(self, job_id: int, timeout_s: float = 600.0,
               poll_s: float = 0.05):
        """Block until ``job_id`` finishes; returns its ``SubStratResult``."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            status, body = self._request(f"/v1/result?job_id={job_id}")
            if status == 200:
                return wire.loads(body)
            if status == 202:
                time.sleep(poll_s)
                continue
            raise RuntimeError(f"result failed ({status}): {body!r}")
        raise TimeoutError(f"job {job_id} still active after {timeout_s}s")

    def stats(self) -> dict:
        status, body = self._request("/v1/stats")
        if status != 200:
            raise RuntimeError(f"stats failed ({status}): {body!r}")
        return self._json(body)

    def metrics(self) -> str:
        """The server's Prometheus text exposition (``/v1/metrics``)."""
        status, body = self._request("/v1/metrics")
        if status != 200:
            raise RuntimeError(f"metrics failed ({status}): {body!r}")
        return body.decode("utf-8")

    def trace(self, job_id: int) -> dict:
        """One job's span records: ``{"job_id", "trace_id", "spans"}`` —
        feed ``spans`` to ``obs.trace.render_timeline`` for the ASCII view."""
        status, body = self._request(f"/v1/trace?job_id={job_id}")
        if status != 200:
            raise RuntimeError(f"trace failed ({status}): {body!r}")
        return self._json(body)
