"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def _axis_types_kw(n_axes: int) -> dict:
    """Explicit-Auto axis types where the jax version supports them.

    jax >= 0.6 exposes ``jax.sharding.AxisType`` and ``make_mesh`` accepts
    ``axis_types``; older versions (0.4.x) have neither — Auto is already
    their only behavior, so the kwarg is simply omitted.
    """
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return {}
    return {"axis_types": (at.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) (pod, data, model) = 512 chips; ``pod`` is an
    outer data axis crossing DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    return jax.make_mesh(
        shape, axes,
        devices=jax.devices()[:n],
        **_axis_types_kw(len(axes)),
    )


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / smoke runs)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        **_axis_types_kw(len(axes)),
    )
