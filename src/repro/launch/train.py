"""Training launcher: ``python -m repro.launch.train --arch <id> [options]``.

Wires together the whole stack: arch registry -> data pipeline (with
optional SubStrat corpus-subset selection) -> sharded train step ->
fault-tolerant loop with async checkpoints.

On this CPU container use ``--preset cpu-small`` (reduced config); the full
configs are exercised by the dry-run (``repro.launch.dryrun``).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data.pipeline import ShardedLoader, SyntheticCorpus, select_corpus_subset
from repro.distributed.checkpoint import CheckpointManager, restore_latest
from repro.train.optimizer import make_optimizer, warmup_cosine
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--preset", choices=["cpu-small", "full"], default="cpu-small")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--corpus-seqs", type=int, default=2048)
    ap.add_argument("--substrat-subset", type=int, default=0,
                    help="if >0, train on an entropy-preserving corpus subset "
                         "of this many sequences (SubStrat step 1)")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = ARCHS[args.arch]
    cfg = arch.smoke if args.preset == "cpu-small" else arch.config
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit(f"{args.arch}: use examples/ drivers for multimodal "
                         "input plumbing; train.py covers token-LM archs")

    corpus = SyntheticCorpus(args.corpus_seqs, args.seq + 1, cfg.vocab_size, seed=0)
    subset = None
    if args.substrat_subset:
        t0 = time.time()
        subset = select_corpus_subset(corpus, args.substrat_subset,
                                      sample_rows=min(args.corpus_seqs, 4096))
        print(f"[substrat] selected {len(subset)} / {len(corpus)} sequences "
              f"in {time.time()-t0:.1f}s")
    loader = ShardedLoader(corpus, args.batch, seed=0, subset=subset)

    opt = make_optimizer(
        arch.optimizer,
        warmup_cosine(args.lr or arch.peak_lr, warmup=20, total=args.steps),
    )
    state = init_train_state(jax.random.key(0), cfg, opt)
    step_fn = jax.jit(make_train_step(cfg, opt, accum_steps=args.accum),
                      donate_argnums=(0,))

    ckpt = CheckpointManager(Path(args.ckpt_dir) / args.arch)
    restored = restore_latest(ckpt.dir, state)
    start = 0
    if restored is not None:
        state, start = restored
        start += 1
        loader.restore(type(loader.state())(start))
        print(f"[ckpt] resumed from step {start - 1}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in loader.next().items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            dt = (time.time() - t0) / max(step - start + 1, 1)
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms/step",
                  flush=True)
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step, state)
    ckpt.wait()
    print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s")
    return state


if __name__ == "__main__":
    main()
