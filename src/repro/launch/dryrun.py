import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh (16,16) or (2,16,16) from placeholder
     host devices (the XLA_FLAGS line above MUST run before any jax import);
  2. resolves sharding rules, constructs ShapeDtypeStruct stand-ins for the
     train state / serve operands (zero allocation);
  3. ``jit(step).lower(...).compile()`` — proving the distribution config is
     coherent (sharding propagation, collective legality, memory fit);
  4. records memory_analysis / cost_analysis / per-class collective bytes
     (parsed from the partitioned HLO) and the three roofline terms into
     ``experiments/dryrun.json`` (incremental; reruns skip completed cells).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh multi
"""
import argparse
import gc
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch, input_specs, decode_operand_specs
from repro.distributed.sharding import (
    batch_specs, cache_specs, param_specs, opt_state_specs, rules_for,
    tree_shardings,
)
from repro.launch.flops import model_flops, active_params
from repro.launch.hlo_costs import analyze_hlo, xla_cost_dict
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, lm
from repro.models.config import SHAPES, ShapeSpec
from repro.train.optimizer import make_optimizer, warmup_cosine
from repro.train.train_step import TrainState, make_serve_step, make_train_step

# TPU v5e-ish hardware model (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

OUT_PATH = Path(__file__).resolve().parents[3] / "experiments" / "dryrun.json"


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def _serve_params_struct(cfg):
    """Parameter ShapeDtypeStructs in serving dtype (bf16)."""
    init_fn = encdec.init_params if cfg.family == "encdec" else lm.init_params
    shapes = jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.key(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype
        ),
        shapes,
    )


def build_cell(arch_id: str, shape: ShapeSpec, mesh):
    """Returns (fn, arg_structs, in_shardings) for jit lowering."""
    arch = get_arch(arch_id)
    cfg = arch.config
    mode = "train" if shape.kind == "train" else shape.kind
    rules = rules_for(cfg, mesh, mode)
    if arch.dp_over_model:
        rules["batch"] = tuple(mesh.axis_names)

    def _valid_batch_prefix(size: int):
        axes = rules["batch"]
        axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
        names, prod = [], 1
        for a in axes:
            if size % (prod * mesh.shape[a]) == 0:
                names.append(a)
                prod *= mesh.shape[a]
            else:
                break
        return tuple(names), prod

    # sequence-sharded residuals for big dense/vlm training (keeps the
    # per-layer saved activations within HBM — DESIGN.md §6)
    from repro.launch.flops import active_params as _ap
    from repro.distributed.sharding import param_count_estimate, data_axes
    import dataclasses as _dc
    pcount = param_count_estimate(cfg)
    seq_ok = shape.seq_len % mesh.shape["model"] == 0
    if (
        cfg.family in ("dense", "vlm", "moe") and seq_ok
        and ((shape.kind == "train" and pcount >= 2e9)
             or (shape.kind == "prefill" and pcount >= 8e9))
    ):
        # sequence-sharded residuals (Megatron-SP style): per-layer saved
        # activations and attention scores shard over the model axis.
        cfg = _dc.replace(cfg, act_shard_spec=(data_axes(mesh), "model", None))
    else:
        # pin the residual's batch sharding through the layer/ssm scan
        # carries (observed: GSPMD drops batch sharding inside carries for
        # scan-heavy families and long prefills).  Use the longest mesh-axis
        # prefix that divides the per-call batch (microbatch for train).
        accum_eff = 1
        if shape.kind == "train":
            _, dshards = _valid_batch_prefix(shape.global_batch)
            accum_eff = max(1, min(arch.grad_accum, shape.global_batch // max(dshards, 1)))
        per_call = shape.global_batch // accum_eff
        names, _ = _valid_batch_prefix(per_call)
        if names:
            entry = names[0] if len(names) == 1 else tuple(names)
            cfg = _dc.replace(cfg, act_shard_spec=(entry, None, None))
    if (
        shape.kind == "train" and pcount >= 2e9
        and cfg.family in ("dense", "vlm", "moe")
        and cfg.d_model % mesh.shape["model"] == 0
        and cfg.d_model % mesh.shape["data"] == 0
        and cfg.d_ff % mesh.shape["model"] == 0
    ):
        # custom-VJP grad sharding (see models/pmm.py)
        cfg = _dc.replace(
            cfg, grad_shard=True,
            mesh_data_size=mesh.shape["data"],
            mesh_model_size=mesh.shape["model"],
        )
    if cfg.family == "moe" and cfg.n_experts % mesh.shape["model"] == 0:
        cfg = _dc.replace(cfg, moe_ep_shard=True)

    if shape.kind == "train":
        optimizer = make_optimizer(arch.optimizer, warmup_cosine(arch.peak_lr))
        state_struct = jax.eval_shape(
            lambda k: _init_state_for(cfg, optimizer, k), jax.random.key(0)
        )
        pspecs = param_specs(state_struct.params, cfg, mesh, rules)
        ospecs = opt_state_specs(state_struct.opt_state, pspecs, state_struct.params, mesh)
        state_specs = TrainState(P(), pspecs, ospecs)
        batch = input_specs(cfg, shape)
        bspecs = batch_specs(batch, mesh, rules)
        _, dshards = _valid_batch_prefix(shape.global_batch)
        accum = max(1, min(arch.grad_accum, shape.global_batch // max(dshards, 1)))
        baxes = rules["batch"]
        baxes = (baxes,) if isinstance(baxes, str) else tuple(baxes or ())
        step = make_train_step(
            cfg, optimizer, accum_steps=accum,
            batch_axes=tuple((a, mesh.shape[a]) for a in baxes),
        )
        in_sh = (tree_shardings(state_specs, mesh), tree_shardings(bspecs, mesh))
        out_sh = (tree_shardings(state_specs, mesh), None)
        # donate the train state: params/opt buffers update in place
        return step, (state_struct, batch), in_sh, out_sh, (0,)

    params = _serve_params_struct(cfg)
    pspecs = param_specs(params, cfg, mesh, rules)
    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bspecs = batch_specs(batch, mesh, rules)
        step = make_serve_step(cfg, "prefill", max_len=None)
        in_sh = (tree_shardings(pspecs, mesh), tree_shardings(bspecs, mesh))
        # pin the output cache layout (otherwise GSPMD may replicate it)
        with mesh:
            out_struct = jax.eval_shape(step, params, batch)
        ocspecs = cache_specs(out_struct[1], cfg, mesh, rules)
        out_sh = (None, tree_shardings(ocspecs, mesh))
        return step, (params, batch), in_sh, out_sh, ()

    # decode: donate the KV cache / state (updated in place)
    cache, token, pos, pos_ref = decode_operand_specs(cfg, shape)
    cspecs = cache_specs(cache, cfg, mesh, rules)
    tspec = batch_specs({"t": token}, mesh, rules)["t"]
    step = make_serve_step(cfg, "decode")
    in_sh = (
        tree_shardings(pspecs, mesh),
        tree_shardings(cspecs, mesh),
        NamedSharding(mesh, tspec),
        NamedSharding(mesh, P()),
    )
    out_sh = (None, tree_shardings(cspecs, mesh))
    return step, (params, cache, token, pos), in_sh, out_sh, (1,)


def _init_state_for(cfg, optimizer, key):
    init_fn = encdec.init_params if cfg.family == "encdec" else lm.init_params
    params = init_fn(key, cfg)
    return TrainState(jnp.zeros((), jnp.int32), params, optimizer.init(params))


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


def roofline(hcost, n_chips, cfg, shape):
    flops_dev = float(hcost.flops)
    bytes_dev = float(hcost.bytes)
    coll_dev = float(hcost.collective_bytes)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    mflops = model_flops(cfg, shape)
    t_model = mflops / (n_chips * PEAK_FLOPS)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": mflops,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "useful_flops_ratio": mflops / max(flops_dev * n_chips, 1.0),
        "roofline_fraction": t_model / max(bound, 1e-12),
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch_id: str, shape: ShapeSpec, multi_pod: bool, verbose=True):
    arch = get_arch(arch_id)
    reason = arch.skip_reason(shape.name)
    if reason:
        return {"status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(arch_id, shape, mesh)
    jit_kwargs = {"in_shardings": in_sh, "donate_argnums": donate}
    if out_sh is not None:
        jit_kwargs["out_shardings"] = out_sh
    with mesh:
        lowered = jax.jit(fn, **jit_kwargs).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    xla_cost = xla_cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    hcost = analyze_hlo(hlo)
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_per_device_gb": (
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ) / 1e9,
    }
    cfg = arch.config
    result = {
        "status": "ok",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem,
        "collectives": {
            k: v for k, v in hcost.collective_stats.items() if v["count"]
        },
        "n_while": hcost.n_while,
        "trip_counts": hcost.trip_counts,
        "xla_cost_analysis": {  # cross-check only (undercounts loop bodies)
            "flops": float(xla_cost.get("flops", 0.0)),
            "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
        },
        "roofline": roofline(hcost, n_chips, cfg, shape),
        "fits_16gb": mem["peak_per_device_gb"] <= 16.0,
    }
    if verbose:
        r = result["roofline"]
        print(
            f"  [{result['mesh']}] {arch_id} × {shape.name}: "
            f"compile {t_compile:.0f}s, peak {mem['peak_per_device_gb']:.2f} GB/dev, "
            f"compute {r['compute_s']*1e3:.2f}ms / memory {r['memory_s']*1e3:.2f}ms / "
            f"coll {r['collective_s']*1e3:.2f}ms → {r['dominant']}-bound, "
            f"roofline_frac {r['roofline_fraction']:.3f}", flush=True,
        )
    del compiled, lowered, fn, args
    gc.collect()
    return result


def _shape_for(arch_id, shape: ShapeSpec) -> ShapeSpec:
    return shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--out", default=str(OUT_PATH))
    args = ap.parse_args()

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [s for s in SHAPES if args.shape in (None, s.name)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch_id in archs:
        for shape in shapes:
            for multi in meshes:
                key = f"{arch_id}|{shape.name}|{'multi' if multi else 'single'}"
                if key in results and results[key].get("status") in ("ok", "skipped") \
                        and not args.force:
                    continue
                print(f"cell {key} ...", flush=True)
                try:
                    results[key] = run_cell(arch_id, shape, multi)
                except Exception as e:  # noqa: BLE001 — record and continue
                    results[key] = {"status": "failed", "error": f"{type(e).__name__}: {e}"}
                    print(f"  FAILED: {type(e).__name__}: {e}", flush=True)
                st = results[key]["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "failed"
                out_path.write_text(json.dumps(results, indent=1))
    total_ok = sum(1 for v in results.values() if v["status"] == "ok")
    total_skip = sum(1 for v in results.values() if v["status"] == "skipped")
    total_fail = sum(1 for v in results.values() if v["status"] == "failed")
    print(f"\ndry-run complete: {total_ok} ok, {total_skip} skipped, {total_fail} failed "
          f"(of {len(results)} cells) → {out_path}")
    if total_fail:
        for k, v in results.items():
            if v["status"] == "failed":
                print(f"  FAIL {k}: {v['error'][:200]}")


if __name__ == "__main__":
    main()
