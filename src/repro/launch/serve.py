"""Serving launcher: batched prefill + decode loop for any --arch.

``python -m repro.launch.serve --arch mamba2-130m --prompt-len 32 --gen 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import lm
from repro.train.train_step import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--preset", choices=["cpu-small", "full"], default="cpu-small")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    arch = ARCHS[args.arch]
    cfg = arch.smoke if args.preset == "cpu-small" else arch.config
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("use examples/ drivers for multimodal archs")

    params = lm.init_params(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cfg, max_len=max_len))
    decode = jax.jit(
        lambda p, c, t, pos: lm.decode(p, c, t, pos, cfg),
        donate_argnums=(1,),
    )

    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    logits.block_until_ready()
    t_prefill = time.time() - t0

    key = jax.random.key(2)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len} tokens")
    print(f"decode : {t_decode/max(args.gen-1,1)*1e3:.1f} ms/token "
          f"({args.batch * (args.gen-1) / max(t_decode,1e-9):.1f} tok/s batch)")
    print("sampled token ids:", gen[0][:16])
    return gen


if __name__ == "__main__":
    main()
