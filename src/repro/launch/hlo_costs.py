"""HLO cost engine: FLOPs / HBM bytes / collective bytes from the compiled,
partitioned HLO text — with while-loop bodies scaled by their trip counts.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
computation once, so ``lax.scan``-over-layers (and gradient-accumulation
loops) are counted at 1/L of their true cost — verified empirically (a
126-layer scanned model reported ~1/700 of its analytic FLOPs).  This
module rebuilds the cost walk over the parsed module:

  * ``while`` ops multiply their body/condition cost by the trip count
    (extracted from the loop condition's comparison constant);
  * ``fusion`` ops: operand/result bytes are the real HBM surface (post-
    fusion traffic — XLA's own convention); FLOPs recurse into the fused
    computation (dots inside fusions still execute);
  * collectives get per-class byte accounting with ring (k-1)/k factors,
    all-reduce counted twice (reduce + broadcast phases).

All numbers are per-device (the partitioned module's shapes are local).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

__all__ = ["analyze_hlo", "HloCost", "xla_cost_dict"]


def xla_cost_dict(analysis) -> dict:
    """``compiled.cost_analysis()`` compat: newer jax returns one dict,
    jax 0.4.x a per-device list of dicts (the partitioned entries are
    identical — take the first)."""
    if isinstance(analysis, (list, tuple)):
        return analysis[0] if analysis else {}
    return analysis

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]"
)

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}

_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9, ]*\})")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_stats: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES[dt]
        for dt, dims in _SHAPE_RE.findall(type_str)
    )


def _parse(hlo: str):
    comps: Dict[str, List[Instr]] = {}
    types: Dict[str, str] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    header = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s:
            continue
        if cur is None:
            if s.endswith("{"):
                m = header.match(s)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        # operands: %names before the closing paren of the operand list
        depth = 1
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = re.findall(r"%([\w.\-]+)", rest[:end])
        instr = Instr(name, rtype, opcode, operands, s)
        comps[cur].append(instr)
        types[name] = rtype
    return comps, types, entry


def _trip_count(cond_instrs: List[Instr]) -> int:
    best = 1
    for ins in cond_instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, types: Dict[str, str]) -> float:
    result_elems = sum(_shape_elems(d) for _, d in _SHAPE_RE.findall(ins.result_type))
    if not ins.operands:
        return 0.0
    lhs_type = types.get(ins.operands[0], "")
    lhs_shapes = _SHAPE_RE.findall(lhs_type)
    if not lhs_shapes:
        return 0.0
    lhs_dims = [int(x) for x in lhs_shapes[0][1].split(",") if x]
    cm = _CONTRACT_RE.search(ins.line)
    if cm is None:
        k = lhs_dims[-1] if lhs_dims else 1
    else:
        k = 1
        for i in (int(x) for x in cm.group(1).split(",") if x):
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * result_elems * k


def _conv_flops(ins: Instr, types: Dict[str, str]) -> float:
    result_elems = sum(_shape_elems(d) for _, d in _SHAPE_RE.findall(ins.result_type))
    if len(ins.operands) < 2:
        return 0.0
    kshapes = _SHAPE_RE.findall(types.get(ins.operands[1], ""))
    kelems = _shape_elems(kshapes[0][1]) if kshapes else 1
    # rough: per output element, one MAC per kernel element / out-features
    kdims = [int(x) for x in kshapes[0][1].split(",") if x] if kshapes else [1]
    out_feat = max(kdims) if kdims else 1
    return 2.0 * result_elems * max(1, kelems // max(out_feat, 1))


def _op_bytes(ins: Instr, types: Dict[str, str]) -> float:
    b = _type_bytes(ins.result_type)
    for o in ins.operands:
        b += _type_bytes(types.get(o, ""))
    return float(b)


def analyze_hlo(hlo: str) -> HloCost:
    comps, types, entry = _parse(hlo)
    if entry is None:
        entry = next(iter(comps))
    cost = HloCost(
        collective_stats={c: {"count": 0.0, "bytes": 0.0} for c in COLLECTIVES}
    )
    visiting: set = set()

    def walk(comp: str, factor: float, surface: bool):
        if comp not in comps or comp in visiting:
            return
        visiting.add(comp)
        for ins in comps[comp]:
            op = ins.opcode
            if op == "while":
                cost.n_while += 1
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                if bm:
                    cost.trip_counts[bm.group(1)] = trips
                    walk(bm.group(1), factor * trips, surface)
                if cm:
                    walk(cm.group(1), factor * trips, False)
                continue
            if op == "fusion":
                if surface:
                    cost.bytes += factor * _op_bytes(ins, types)
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if fm:
                    walk(fm.group(1), factor, False)
                continue
            if op in ("call", "conditional"):
                for cc in re.findall(r"(?:to_apply|branch_computations|calls)="
                                     r"%?([\w.\-]+)", ins.line):
                    walk(cc, factor, surface)
                continue
            if op == "dot":
                cost.flops += factor * _dot_flops(ins, types)
                if surface:
                    cost.bytes += factor * _op_bytes(ins, types)
                continue
            if op == "convolution":
                cost.flops += factor * _conv_flops(ins, types)
                if surface:
                    cost.bytes += factor * _op_bytes(ins, types)
                continue
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                nbytes = _type_bytes(ins.result_type)
                k = 1
                g2 = _GROUPS_V2_RE.search(ins.line)
                if g2:
                    k = int(g2.group(2))
                else:
                    g = _GROUPS_RE.search(ins.line)
                    if g:
                        k = max(1, g.group(1).count(",") + 1)
                if base == "all-reduce":
                    eff = 2.0 * nbytes * (k - 1) / max(k, 1)
                elif base == "collective-permute":
                    eff = float(nbytes)
                else:
                    eff = nbytes * (k - 1) / max(k, 1)
                cost.collective_stats[base]["count"] += factor
                cost.collective_stats[base]["bytes"] += factor * eff
                cost.collective_bytes += factor * eff
                if surface:
                    cost.bytes += factor * nbytes
                continue
            if surface and op not in _NO_TRAFFIC:
                cost.bytes += factor * _op_bytes(ins, types)
        visiting.discard(comp)

    walk(entry, 1.0, True)
    return cost
