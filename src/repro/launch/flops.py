"""Analytic MODEL_FLOPS per (arch, shape): the "useful" FLOPs yardstick.

Train: 6 * N_active * tokens  (+ causal attention term 6 * S_ctx/2 per
token per layer per qk/v dim).  Prefill: 2 * N_active * tokens + attention.
Decode: per-token matmuls + attention over the cached context.

N_active counts matmul-visible params (embedding lookup excluded, lm_head
included; MoE counts routed experts at top_k/E utilization + shared).

``tabular_trial_flops`` is the serving tier's counterpart for the SubStrat
AutoML trials: the same 6·P-train / 2·P-eval pricing applied to the
batched engine's tabular MLP, used by ``obs/jaxprof.pack_flops`` for
padded-vs-useful megabatch accounting.
"""
from __future__ import annotations

from ..models.config import ModelConfig, ShapeSpec

__all__ = ["active_params", "model_flops", "tabular_trial_flops",
           "gen_dst_generation_flops"]


def tabular_trial_flops(n_tr: int, n_val: int, d: int, n_classes: int,
                        steps: int, hidden: int = 32) -> float:
    """Analytic FLOPs of one tabular AutoML trial: a ``d → hidden →
    n_classes`` MLP trained full-batch for ``steps`` epochs on ``n_tr``
    rows, evaluated once on ``n_val`` rows (6·P per trained example-step,
    2·P per validation example)."""
    p = d * hidden + hidden * n_classes
    return 6.0 * p * float(steps) * float(n_tr) + 2.0 * p * float(n_val)


def gen_dst_generation_flops(phi: int, n: int, M: int, B: int, *,
                             mode: str = "delta",
                             tile_p: int = 8) -> tuple[float, float]:
    """``(useful, launched)`` FLOPs of one Gen-DST generation's fitness pass
    (DESIGN.md §16.5), for the roofline's padded-vs-useful accounting.

    ``useful`` is the algorithmic minimum per live candidate: the
    scatter-equivalent count update — 4 ops/column for a one-row ``delta``
    (subtract old + add new, each a read-modify-write), or ``2·n·M`` adds
    for a ``full`` histogram rebuild — plus the masked-entropy reduction
    (~5 ops per (M, B) histogram cell: normalize, log2, multiply,
    predicate, accumulate).

    ``launched`` is what the fused kernel actually executes: the delta is
    materialized as one-hot compares against the bin iota (6 ops per cell
    instead of 4 per column), the full rebuild as a one-hot matmul
    (``2·n·M·B``), and the candidate axis is padded up to the ``tile_p``
    grid — padded lanes compute a fitness nobody reads.  The histogram
    path's own row-tile padding is not priced here (it varies with the
    entropy kernel's tile_n and is negligible at Gen-DST row counts).
    """
    entropy = 5.0 * M * B
    if mode == "delta":
        useful_pc = 4.0 * M + entropy
        launched_pc = 6.0 * M * B + entropy
    elif mode == "full":
        useful_pc = 2.0 * n * M + entropy
        launched_pc = 2.0 * n * M * B + entropy
    else:
        raise ValueError(f"unknown Gen-DST generation mode: {mode!r}")
    phi_padded = -(-phi // tile_p) * tile_p
    return useful_pc * phi, launched_pc * phi_padded


def _attn_params(cfg: ModelConfig) -> float:
    return cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * cfg.d_model


def _mlp_params(cfg: ModelConfig) -> float:
    return cfg.d_model * cfg.d_ff * (3 if cfg.glu else 2)


def _ssm_params(cfg: ModelConfig) -> float:
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
    return cfg.d_model * d_in_proj + cfg.d_inner * cfg.d_model


def active_params(cfg: ModelConfig) -> float:
    L = cfg.n_layers
    head = cfg.d_model * cfg.vocab_size          # lm_head matmul
    if cfg.family in ("dense", "vlm"):
        return L * (_attn_params(cfg) + _mlp_params(cfg)) + head
    if cfg.family == "moe":
        routed = cfg.moe_top_k * cfg.d_model * cfg.d_ff * 3
        shared = cfg.n_shared_experts * cfg.d_model * cfg.d_ff * 3
        router = cfg.d_model * cfg.n_experts
        return L * (_attn_params(cfg) + routed + shared + router) + head
    if cfg.family == "ssm":
        return L * _ssm_params(cfg) + head
    if cfg.family == "hybrid":
        n_shared = L // (cfg.shared_attn_every or L)
        shared_blk = _attn_params(cfg) + _mlp_params(cfg)
        return L * _ssm_params(cfg) + n_shared * shared_blk + head
    if cfg.family == "encdec":
        enc = cfg.n_enc_layers * (_attn_params(cfg) + _mlp_params(cfg))
        dec = cfg.n_layers * (2 * _attn_params(cfg) + _mlp_params(cfg))
        return enc + dec + head
    raise ValueError(cfg.family)


def _attn_ctx_flops_per_tok(cfg: ModelConfig, ctx: float, n_attn_layers: float) -> float:
    """qk^T + att*v flops for one token attending over ``ctx`` keys."""
    return n_attn_layers * 4 * cfg.n_heads * cfg.head_dim * ctx


def _n_attn_layers(cfg: ModelConfig) -> float:
    if cfg.family in ("dense", "vlm", "moe"):
        return cfg.n_layers
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // (cfg.shared_attn_every or cfg.n_layers)
    if cfg.family == "encdec":
        return 2 * cfg.n_layers + cfg.n_enc_layers  # self+cross dec, self enc
    raise ValueError(cfg.family)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global useful FLOPs for one step of this cell."""
    B, S = shape.global_batch, shape.seq_len
    P = active_params(cfg)
    n_attn = _n_attn_layers(cfg)

    if cfg.family == "encdec":
        S_dec = max(8, S // cfg.dec_ratio)
        tokens = B * (S + S_dec) / 2  # rough: enc runs S, dec runs S_dec
        # attention ctx: enc self S, dec self S_dec/2 causal, cross S
        attn = B * (
            cfg.n_enc_layers * S * 4 * cfg.n_heads * cfg.head_dim * S
            + cfg.n_layers * S_dec * 4 * cfg.n_heads * cfg.head_dim * (S_dec / 2 + S)
        )
    else:
        tokens = B * S
        attn = tokens * _attn_ctx_flops_per_tok(cfg, S / 2, n_attn)
        if cfg.family in ("ssm", "hybrid"):
            # SSD: state update+readout ~ 6 * d_inner * N per token
            attn += tokens * 6 * cfg.d_inner * cfg.ssm_state * cfg.n_layers

    if shape.kind == "train":
        return 6 * P * tokens + 3 * attn
    if shape.kind == "prefill":
        return 2 * P * tokens + attn
    # decode: one new token per sequence, full-context attention
    per_tok = 2 * P + _attn_ctx_flops_per_tok(cfg, S, n_attn)
    if cfg.family in ("ssm", "hybrid"):
        per_tok = 2 * P + _attn_ctx_flops_per_tok(cfg, S, n_attn) \
            + 6 * cfg.d_inner * cfg.ssm_state * cfg.n_layers
    if cfg.family == "encdec":
        S_dec = max(8, S // cfg.dec_ratio)
        per_tok = 2 * (P - cfg.n_enc_layers * (_attn_params(cfg) + _mlp_params(cfg))) \
            + cfg.n_layers * 4 * cfg.n_heads * cfg.head_dim * (S_dec + S)
    return B * per_tok
