"""A search-based AutoML engine ``A(D, y) -> M*`` in JAX.

Pipeline configuration = (preprocessor, feature-selector, model family, HPs);
the full search-space tables live in DESIGN.md §10.1.  The engine runs random
sampling + successive halving on the ``epochs`` resource (rung/keep_frac
semantics: DESIGN.md §10.2), under a trial or wall-clock budget, and returns
the best pipeline by validation accuracy — our stand-in for Auto-Sklearn/TPOT
(DESIGN.md §5.4).

Two execution backends share one rung loop (``AutoMLConfig.backend``):

- ``"batched"`` (default): the whole rung cohort is padded/stacked into
  struct-of-arrays params and advanced by per-family ``jax.vmap``-ed training
  in ``automl/batched.py`` — one jitted ``lax.scan`` per family sub-batch
  instead of one per trial (DESIGN.md §10.3).
- ``"loop"``: the sequential reference path, one ``train_model`` call per
  trial.  Kept for parity testing; same-seed runs produce the same winner
  because both backends derive per-trial PRNG keys from
  ``(seed, trial_id, rung)`` rather than evaluation order.

Successive-halving promotion is an on-device top-k mask (``sh_promote``)
applied identically by both backends.

The paper's fine-tuning step (§3.4) maps to ``restrict_family=...``: a
restricted, much shorter search that only considers pipelines using the same
model family as the intermediate configuration M'.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.jaxprof import note_trace
from .models import FAMILIES, accuracy, train_model

__all__ = [
    "AutoMLConfig", "AutoMLResult", "automl_fit", "PipelineSpec",
    "apply_pipeline", "sh_promote", "SearchState", "search_init",
    "search_cohort", "search_record", "search_result", "search_eval_rung",
    "TrialCohort", "search_trial_cohort", "register_backend", "get_backend",
    "available_backends", "BACKENDS", "search_snapshot", "search_restore",
]

# preprocessor and feature-fraction axes of the pipeline search space
# (DESIGN.md §10.1)
PREPROCS = ("none", "standardize", "minmax")
FEATURE_FRACS = (1.0, 0.5)


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """One point of the pipeline search space (DESIGN.md §10.1)."""
    preproc: str        # one of PREPROCS
    feature_frac: float  # one of FEATURE_FRACS (variance-ranked top-k columns)
    family: str         # key into models.FAMILIES
    hp: tuple           # sorted (k, v) tuple from the family's hp_grid


@dataclasses.dataclass
class AutoMLResult:
    spec: PipelineSpec
    params: Any
    val_acc: float
    test_acc: Optional[float]
    time_s: float
    n_trials: int
    feat_idx: np.ndarray
    pre_stats: Dict[str, np.ndarray]
    trials: List[tuple]        # (spec, val_acc), cohort order per rung
    rung_times: List[float] = dataclasses.field(default_factory=list)
    backend: str = "batched"


@dataclasses.dataclass(frozen=True)
class AutoMLConfig:
    """Budget + schedule of one ``automl_fit`` search (DESIGN.md §10.2).

    Every field is anchored in the docs; see DESIGN.md §10 for the full
    execution model.
    """
    n_trials: int = 24                       # sampled population size (§10.2)
    time_budget_s: Optional[float] = None    # wall-clock cutoff (paper §4.1 budgets)
    rungs: Sequence[int] = (20, 60, 180)     # successive-halving epoch rungs (§10.2)
    keep_frac: float = 0.34                  # survivor fraction per rung (§10.2)
    val_frac: float = 0.2                    # holdout fraction scored by accuracy (§5.4)
    seed: int = 0                            # PRNG seed; trial keys fold in (id, rung)
    backend: str = "batched"                 # "batched" (§10.3) | "loop" (reference)


def _fit_preproc(name: str, X: np.ndarray) -> Dict[str, np.ndarray]:
    if name == "standardize":
        return {"mu": X.mean(0), "sd": X.std(0) + 1e-9}
    if name == "minmax":
        return {"lo": X.min(0), "hi": X.max(0)}
    return {}


def _apply_preproc(name: str, stats, X: np.ndarray) -> np.ndarray:
    if name == "standardize":
        return (X - stats["mu"]) / stats["sd"]
    if name == "minmax":
        rng = np.maximum(stats["hi"] - stats["lo"], 1e-9)
        return (X - stats["lo"]) / rng * 2.0 - 1.0
    return X


def _select_features(frac: float, X_train: np.ndarray, y_train: np.ndarray) -> np.ndarray:
    d = X_train.shape[1]
    k = max(1, int(round(frac * d)))
    if k >= d:
        return np.arange(d)
    # variance ranking (cheap, label-free)
    var = X_train.var(axis=0)
    return np.argsort(-var)[:k]


def apply_pipeline(spec: PipelineSpec, pre_stats, feat_idx, X: np.ndarray) -> jnp.ndarray:
    Xp = _apply_preproc(spec.preproc, pre_stats, X)
    return jnp.asarray(Xp[:, feat_idx], dtype=jnp.float32)


def _trial_key(seed: int, trial_id: int, rung_i: int) -> jax.Array:
    """Per-trial PRNG key, independent of evaluation order.

    Both backends derive keys from ``(seed, trial_id, rung)`` so the batched
    cohort and the sequential loop train bit-identical trajectories for the
    same sampled population (DESIGN.md §10.4)."""
    return jax.random.fold_in(jax.random.fold_in(jax.random.key(seed), trial_id), rung_i)


@functools.partial(jax.jit, static_argnames=("keep",))
def _promote_mask(val_acc, *, keep: int):
    note_trace("engine._promote_mask")   # body runs only while tracing
    order = jnp.argsort(-val_acc, stable=True)
    return jnp.zeros(val_acc.shape, bool).at[order[:keep]].set(True)


def sh_promote(val_acc, keep_frac: float) -> jax.Array:
    """Successive-halving promotion as an on-device top-k survivor mask.

    Keeps ``max(1, ceil(n * keep_frac))`` trials; ties broken toward the
    lower trial index (stable sort), matching the sequential reference
    semantics (DESIGN.md §10.2)."""
    val_acc = np.asarray(val_acc, np.float32)
    keep = max(1, int(np.ceil(val_acc.shape[0] * keep_frac)))
    return _promote_mask(val_acc, keep=keep)


def _sample_specs(rng: np.random.Generator, n: int, families: Sequence[str]) -> List[PipelineSpec]:
    specs = []
    for _ in range(n):
        fam = families[rng.integers(len(families))]
        grid = FAMILIES[fam].hp_grid
        hp = tuple(sorted((k, v[rng.integers(len(v))]) for k, v in grid.items()))
        specs.append(
            PipelineSpec(
                preproc=PREPROCS[rng.integers(len(PREPROCS))],
                feature_frac=FEATURE_FRACS[rng.integers(len(FEATURE_FRACS))],
                family=fam,
                hp=hp,
            )
        )
    # dedup, keep order
    seen, out = set(), []
    for s in specs:
        key = (s.preproc, s.feature_frac, s.family, s.hp)
        if key not in seen:
            seen.add(key)
            out.append(s)
    return out


def _eval_rung_loop(cohort, tids, rung_i, epochs, ctx, out_of_budget,
                    collect_params=True):
    """Sequential reference: one ``train_model`` call per trial.

    Returns ``(scored, positions)`` like ``batched.eval_rung_batched``
    (params come for free here, so ``collect_params`` is ignored)."""
    scored = []
    for spec, tid in zip(cohort, tids):
        if out_of_budget() and scored:
            break
        ckey = (spec.preproc, spec.feature_frac)
        if ckey not in ctx["pipe_cache"]:
            stats = _fit_preproc(spec.preproc, ctx["X_tr"])
            fidx = _select_features(spec.feature_frac, ctx["X_tr"], ctx["y_tr"])
            Xtr_p = apply_pipeline(spec, stats, fidx, ctx["X_tr"])
            Xval_p = apply_pipeline(spec, stats, fidx, ctx["X_val"])
            ctx["pipe_cache"][ckey] = (stats, fidx, Xtr_p, Xval_p)
        stats, fidx, Xtr_p, Xval_p = ctx["pipe_cache"][ckey]
        params = train_model(
            _trial_key(ctx["seed"], tid, rung_i),
            Xtr_p, ctx["y_tr_j"], spec.family, ctx["n_classes"], dict(spec.hp), epochs,
        )
        vacc = accuracy(params, Xval_p, ctx["y_val_j"], spec.family)
        scored.append((spec, vacc, params, fidx, stats))
    return scored, list(range(len(scored)))


# ---------------------------------------------------------------------------
# SearchBackend registry: "how one rung of trials is evaluated"
# ---------------------------------------------------------------------------

# A backend is a rung evaluator:
#   (cohort, tids, rung_i, epochs, ctx, out_of_budget, collect_params)
#     -> (scored, positions)
# where ``scored[i]`` is the loop-backend tuple
# ``(spec, val_acc, params, feat_idx, pre_stats)`` and ``positions[i]`` its
# index into ``cohort``.  ``AutoMLConfig.backend`` and Plan backends resolve
# through this registry, so third parties can plug in their own evaluator
# (distributed, quantized, ...) without touching the engine (DESIGN.md §12.2).
BACKENDS: Dict[str, Any] = {}


def register_backend(name: str, eval_rung, *, overwrite: bool = False):
    """Register a SearchBackend rung evaluator under ``name``."""
    if not overwrite and name in BACKENDS:
        raise ValueError(f"backend {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    BACKENDS[name] = eval_rung
    return eval_rung


def available_backends():
    return tuple(sorted(BACKENDS))


def get_backend(name: str):
    """Look up a registered backend; unknown names list what exists."""
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown AutoML backend {name!r}; available backends: "
            f"{', '.join(available_backends())}") from None


def _eval_rung_batched_lazy(cohort, tids, rung_i, epochs, ctx, out_of_budget,
                            collect_params=True):
    # deferred import: batched.py imports engine helpers (no cycle at load)
    from .batched import eval_rung_batched
    return eval_rung_batched(cohort, tids, rung_i, epochs, ctx, out_of_budget,
                             collect_params)


register_backend("loop", _eval_rung_loop)
register_backend("batched", _eval_rung_batched_lazy)


@dataclasses.dataclass
class SearchState:
    """Resumable state of one successive-halving search (DESIGN.md §11.3).

    ``automl_fit`` drives a state rung-by-rung to completion; the service
    scheduler instead advances many states in lockstep so compatible rung
    cohorts from different jobs can merge into one batched dispatch
    (``automl/batched.eval_rung_cohorts``).  The cycle per rung is
    ``search_cohort`` (what to evaluate) → any backend evaluation →
    ``search_record`` (promotion + advance); ``search_result`` finalizes.
    """
    config: AutoMLConfig
    classes: np.ndarray                # original label values, sorted
    ctx: dict                          # backend evaluation context
    specs: List[PipelineSpec]
    alive_ids: List[int]
    t_start: float
    rung_i: int = 0
    live: List[tuple] = dataclasses.field(default_factory=list)
    trials_log: List[tuple] = dataclasses.field(default_factory=list)
    rung_times: List[float] = dataclasses.field(default_factory=list)
    n_done: int = 0
    stopped: bool = False              # budget cutoff fired after a rung
    # per-trial rung cursors (DESIGN.md §13.2): ``trial_rung[tid]`` is the
    # rung the trial trains *next*.  Within one search every live trial sits
    # at ``rung_i`` (SH promotion needs the whole cohort scored before
    # anyone advances), but the cursors are what a megabatch dispatch reads:
    # trials from *different* searches carry different cursors into one
    # standing dispatch (``batched.eval_trial_megabatch``), and a culled
    # trial's cursor simply stops advancing — it has left the megabatch.
    trial_rung: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def done(self) -> bool:
        return self.stopped or self.rung_i >= len(self.config.rungs)

    def out_of_budget(self) -> bool:
        return (
            self.config.time_budget_s is not None
            and time.perf_counter() - self.t_start > self.config.time_budget_s
        )


def search_init(
    X: np.ndarray,
    y: np.ndarray,
    *,
    config: AutoMLConfig = AutoMLConfig(),
    restrict_family: Optional[str] = None,
    seed_trials: Optional[Sequence[PipelineSpec]] = None,
) -> SearchState:
    """Build the evaluation context and sample the initial population.

    ``seed_trials`` is the meta-learning warm-start hook (DESIGN.md §17.4):
    when given, rung 0 runs *only* those specs instead of the whole sampled
    population.  The sampled population depends only on ``config.seed``
    (never on the data), so a seed spec that matches a sampled one keeps
    the sampled trial id — and with it the exact ``(seed, trial_id, rung)``
    PRNG key a cold run would use, making the warm trial's accuracy at
    every rung bit-identical to the corresponding cold trial's.  Seed specs
    outside the population are appended with fresh ids (still
    deterministic).  ``seed_trials=None`` (or empty) is byte-for-byte the
    pre-warm-start cold path."""
    get_backend(config.backend)   # unknown names raise, listing the registry
    t_start = time.perf_counter()
    X = np.asarray(X, dtype=np.float32)
    y = np.asarray(y)
    classes, y_enc = np.unique(y, return_inverse=True)
    n_classes = len(classes)
    rng = np.random.default_rng(config.seed)

    # train/val split
    N = X.shape[0]
    perm = rng.permutation(N)
    n_val = max(1, int(config.val_frac * N))
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    X_tr, y_tr = X[tr_idx], y_enc[tr_idx]
    X_val, y_val = X[val_idx], y_enc[val_idx]

    families = [restrict_family] if restrict_family else list(FAMILIES)
    n_seed_trials = config.n_trials if not restrict_family else max(4, config.n_trials // 4)
    specs = _sample_specs(rng, n_seed_trials, families)
    alive_ids = list(range(len(specs)))
    if seed_trials:
        # warm start: keep only the seeded specs alive.  Matches inherit the
        # sampled trial id (bit-identical PRNG trajectory vs the cold run);
        # novel specs append after the population with fresh ids.
        index = {s: i for i, s in enumerate(specs)}
        ids = []
        for s in seed_trials:
            i = index.get(s)
            if i is None:
                specs.append(s)
                i = len(specs) - 1
                index[s] = i
            ids.append(i)
        alive_ids = sorted(set(ids))

    ctx = {
        "X_tr": X_tr, "y_tr": y_tr, "X_val": X_val, "y_val": y_val,
        "y_tr_j": jnp.asarray(y_tr), "y_val_j": jnp.asarray(y_val),
        "n_classes": n_classes, "seed": config.seed,
        "budget_active": config.time_budget_s is not None,
        "pipe_cache": {},      # loop backend: (preproc, frac) -> projected data
        "variant_cache": {},   # batched backend: (preproc, frac) -> full-width variant
    }
    return SearchState(
        config=config, classes=classes, ctx=ctx, specs=specs,
        alive_ids=alive_ids, t_start=t_start,
        trial_rung={i: 0 for i in alive_ids},
    )


def search_cohort(state: SearchState):
    """Current rung's work unit: ``(cohort, tids, epochs, collect_params)``.

    ``collect_params`` is False on non-final rungs (promotion only needs
    accuracies) — unless a time budget could make this rung the last one
    evaluated."""
    config = state.config
    cohort = [state.specs[i] for i in state.alive_ids]
    collect = (state.rung_i == len(config.rungs) - 1
               or config.time_budget_s is not None)
    return cohort, list(state.alive_ids), int(config.rungs[state.rung_i]), collect


class TrialCohort(NamedTuple):
    """One job's current rung as a uniform, mergeable unit of trial work.

    Every search emits ``TrialCohort``s regardless of which strategy found
    its subset or which backend evaluates it — this is the currency the
    scheduler's cross-job merge layers trade in: same-shaped cohorts fuse
    exactly, differently-shaped ones fuse through maximal-shape padding
    (DESIGN.md §12.3), and cohorts sitting at *different* rungs fuse through
    per-trial step masks (``batched.eval_trial_megabatch``, §13).

    ``rungs``/``steps`` carry each trial's rung cursor and remaining epoch
    budget (from ``SearchState.trial_rung``); the scalar ``rung_i``/
    ``epochs`` remain the uniform-rung view used by the same-rung merge
    entry (``eval_rung_cohorts``) and the lockstep scheduler buckets."""
    specs: list            # PipelineSpec per live trial
    tids: list             # trial ids (PRNG key derivation)
    rung_i: int
    epochs: int
    collect: bool          # params wanted (final rung / budget active)
    ctx: dict              # the SearchState evaluation context
    rungs: tuple = ()      # per-trial rung cursors (§13.2)
    steps: tuple = ()      # per-trial epoch budgets at those cursors

    @property
    def shape(self):
        """(N_tr, N_val, d, n_classes) — the merge-compatibility axes."""
        return (self.ctx["X_tr"].shape[0], self.ctx["X_val"].shape[0],
                self.ctx["X_tr"].shape[1], self.ctx["n_classes"])

    @property
    def trial_rungs(self):
        """Per-trial rungs, defaulting to the uniform ``rung_i``."""
        return self.rungs if self.rungs else (self.rung_i,) * len(self.specs)

    @property
    def trial_steps(self):
        """Per-trial step budgets, defaulting to the uniform ``epochs``."""
        return self.steps if self.steps else (self.epochs,) * len(self.specs)


def search_trial_cohort(state: SearchState) -> TrialCohort:
    """The current rung of ``state`` as a ``TrialCohort``."""
    cohort, tids, epochs, collect = search_cohort(state)
    rungs = tuple(state.trial_rung.get(t, state.rung_i) for t in tids)
    steps = tuple(int(state.config.rungs[r]) for r in rungs)
    return TrialCohort(cohort, tids, state.rung_i, epochs, collect, state.ctx,
                       rungs, steps)


def search_record(state: SearchState, scored, positions, rung_time: float) -> None:
    """Record one evaluated rung: log trials, promote survivors, advance.

    ``scored``/``positions`` are the backend's rung output (loop-backend
    tuple layout).  Promotion is the on-device top-k mask shared by both
    backends; survivors keep population order except under a time budget
    (DESIGN.md §10.2)."""
    config = state.config
    state.rung_times.append(rung_time)
    state.trials_log.extend((s, v) for (s, v, *_rest) in scored)
    state.n_done += len(scored)
    state.live = scored
    # on-device top-k promotion; survivors keep population order — except
    # under a time budget, where the next rung runs best-first so a
    # mid-rung cutoff spends the remaining budget on the strongest trials
    mask = np.asarray(sh_promote(
        np.asarray([v for (_s, v, *_r) in scored], np.float32), config.keep_frac))
    surv = list(np.flatnonzero(mask))
    if config.time_budget_s is not None:
        surv.sort(key=lambda i: (-scored[i][1], i))
    state.alive_ids = [state.alive_ids[positions[i]] for i in surv]
    state.rung_i += 1
    # survivors' cursors advance to the next rung; culled trials keep their
    # last cursor — they have left the standing megabatch (DESIGN.md §13.2)
    for tid in state.alive_ids:
        state.trial_rung[tid] = state.rung_i
    if state.out_of_budget():
        state.stopped = True


def search_result(
    state: SearchState,
    X_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
) -> AutoMLResult:
    """Finalize: pick the accuracy-argmax of the last evaluated rung."""
    live = state.live
    best_i = int(np.argmax([v for (_s, v, *_r) in live]))  # ties -> lower index
    best_spec, best_vacc, best_params, best_fidx, best_stats = live[best_i]
    if callable(best_params):   # batched backend materializes params lazily
        best_params = best_params()
    test_acc = None
    if X_test is not None:
        Xt = apply_pipeline(best_spec, best_stats, best_fidx, np.asarray(X_test, np.float32))
        yt = jnp.asarray(np.searchsorted(state.classes, np.asarray(y_test)))
        test_acc = accuracy(best_params, Xt, yt, best_spec.family)

    return AutoMLResult(
        spec=best_spec,
        params=best_params,
        val_acc=float(best_vacc),
        test_acc=test_acc,
        time_s=time.perf_counter() - state.t_start,
        n_trials=state.n_done,
        feat_idx=best_fidx,
        pre_stats=best_stats,
        trials=state.trials_log,
        rung_times=state.rung_times,
        backend=state.config.backend,
    )


# context keys that cross process boundaries; the jnp mirrors and the
# per-backend caches are derived state, rebuilt on restore
_CTX_SNAPSHOT_KEYS = ("X_tr", "y_tr", "X_val", "y_val", "n_classes", "seed",
                      "budget_active")


def _materialize_scored(scored):
    """Resolve the batched backend's lazy param thunks into real pytrees so
    a scored rung can cross a process boundary (DESIGN.md §14.2)."""
    out = []
    for spec, vacc, params, fidx, stats in scored:
        if callable(params):
            params = params()
        out.append((spec, float(vacc), params, fidx, stats))
    return out


def search_snapshot(state: SearchState) -> dict:
    """A wire-serializable snapshot of one search (DESIGN.md §14.4).

    Captures exactly the state ``search_restore`` needs to continue the
    search bit-identically in another process: the config, the sampled
    population and survivor cursors, the trial log, and the raw evaluation
    data.  Derived device state (jnp label mirrors, the pipe/variant
    caches) is dropped and rebuilt — it is a pure function of the data, so
    resuming reproduces the uninterrupted run exactly.  Lazy param thunks
    in the last scored rung are materialized (wire refuses callables)."""
    ctx = state.ctx
    return {
        "config": state.config,
        "classes": np.asarray(state.classes),
        "specs": list(state.specs),
        "alive_ids": [int(i) for i in state.alive_ids],
        "rung_i": int(state.rung_i),
        "live": _materialize_scored(state.live),
        "trials_log": [(s, float(v)) for s, v in state.trials_log],
        "rung_times": [float(t) for t in state.rung_times],
        "n_done": int(state.n_done),
        "stopped": bool(state.stopped),
        "trial_rung": {int(k): int(v) for k, v in state.trial_rung.items()},
        "elapsed_s": time.perf_counter() - state.t_start,
        "ctx": {k: ctx[k] for k in _CTX_SNAPSHOT_KEYS},
    }


def search_restore(snap: dict) -> SearchState:
    """Rebuild a ``SearchState`` from a ``search_snapshot`` payload.

    The restored search continues from the exact rung boundary the
    snapshot captured; finishing it produces the same winner spec and the
    same trial accuracies as the uninterrupted run (tested across a real
    process boundary in tests/test_wire.py)."""
    ctx = dict(snap["ctx"])
    ctx["X_tr"] = np.asarray(ctx["X_tr"], np.float32)
    ctx["X_val"] = np.asarray(ctx["X_val"], np.float32)
    ctx["y_tr"] = np.asarray(ctx["y_tr"])
    ctx["y_val"] = np.asarray(ctx["y_val"])
    ctx["y_tr_j"] = jnp.asarray(ctx["y_tr"])
    ctx["y_val_j"] = jnp.asarray(ctx["y_val"])
    ctx["n_classes"] = int(ctx["n_classes"])
    ctx["seed"] = int(ctx["seed"])
    ctx["budget_active"] = bool(ctx["budget_active"])
    ctx["pipe_cache"] = {}
    ctx["variant_cache"] = {}
    return SearchState(
        config=snap["config"],
        classes=np.asarray(snap["classes"]),
        ctx=ctx,
        specs=list(snap["specs"]),
        alive_ids=[int(i) for i in snap["alive_ids"]],
        t_start=time.perf_counter() - float(snap["elapsed_s"]),
        rung_i=int(snap["rung_i"]),
        live=[tuple(t) for t in snap["live"]],
        trials_log=[tuple(t) for t in snap["trials_log"]],
        rung_times=list(snap["rung_times"]),
        n_done=int(snap["n_done"]),
        stopped=bool(snap["stopped"]),
        trial_rung={int(k): int(v) for k, v in snap["trial_rung"].items()},
    )


def search_eval_rung(state: SearchState):
    """Evaluate the current rung in-process (single-job path) and record it.

    The service scheduler bypasses this for batched jobs it can merge
    (``automl/batched.eval_rung_cohorts``); everything else — ``automl_fit``,
    loop-backend jobs, time-budgeted jobs — rungs through here."""
    _eval_rung = get_backend(state.config.backend)
    cohort, tids, epochs, collect = search_cohort(state)
    t_rung = time.perf_counter()
    scored, positions = _eval_rung(cohort, tids, state.rung_i, epochs, state.ctx,
                                   state.out_of_budget, collect)
    search_record(state, scored, positions, time.perf_counter() - t_rung)


def automl_fit(
    X: np.ndarray,
    y: np.ndarray,
    *,
    config: AutoMLConfig = AutoMLConfig(),
    restrict_family: Optional[str] = None,
    X_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
) -> AutoMLResult:
    """Run the AutoML search.  Returns the best pipeline found.

    ``restrict_family`` implements the paper's restricted fine-tune pass.
    This is the one-shot driver over the resumable ``SearchState`` API
    (``search_init``/``search_cohort``/``search_record``/``search_result``)
    that the service scheduler uses to interleave many searches."""
    state = search_init(X, y, config=config, restrict_family=restrict_family)
    # successive halving over epoch rungs: each rung retrains the surviving
    # cohort from scratch at the next epoch budget (DESIGN.md §10.2)
    while not state.done:
        search_eval_rung(state)
    return search_result(state, X_test, y_test)
