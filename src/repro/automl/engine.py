"""A search-based AutoML engine ``A(D, y) -> M*`` in JAX.

Pipeline configuration = (preprocessor, feature-selector, model family, HPs).
The engine runs random sampling + successive halving on the ``epochs``
resource, under a trial or wall-clock budget, and returns the best pipeline
by validation accuracy — our stand-in for Auto-Sklearn/TPOT (DESIGN.md §5.4).

The paper's fine-tuning step (§3.4) maps to ``restrict_family=...``: a
restricted, much shorter search that only considers pipelines using the same
model family as the intermediate configuration M'.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .models import FAMILIES, accuracy, train_model

__all__ = ["AutoMLConfig", "AutoMLResult", "automl_fit", "PipelineSpec", "apply_pipeline"]

PREPROCS = ("none", "standardize", "minmax")
FEATURE_FRACS = (1.0, 0.5)


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    preproc: str
    feature_frac: float
    family: str
    hp: tuple  # sorted (k, v) tuple


@dataclasses.dataclass
class AutoMLResult:
    spec: PipelineSpec
    params: Any
    val_acc: float
    test_acc: Optional[float]
    time_s: float
    n_trials: int
    feat_idx: np.ndarray
    pre_stats: Dict[str, np.ndarray]
    trials: List[tuple]  # (spec, val_acc)


@dataclasses.dataclass(frozen=True)
class AutoMLConfig:
    n_trials: int = 24
    time_budget_s: Optional[float] = None
    rungs: Sequence[int] = (20, 60, 180)     # successive-halving epoch rungs
    keep_frac: float = 0.34
    val_frac: float = 0.2
    seed: int = 0


def _fit_preproc(name: str, X: np.ndarray) -> Dict[str, np.ndarray]:
    if name == "standardize":
        return {"mu": X.mean(0), "sd": X.std(0) + 1e-9}
    if name == "minmax":
        return {"lo": X.min(0), "hi": X.max(0)}
    return {}


def _apply_preproc(name: str, stats, X: np.ndarray) -> np.ndarray:
    if name == "standardize":
        return (X - stats["mu"]) / stats["sd"]
    if name == "minmax":
        rng = np.maximum(stats["hi"] - stats["lo"], 1e-9)
        return (X - stats["lo"]) / rng * 2.0 - 1.0
    return X


def _select_features(frac: float, X_train: np.ndarray, y_train: np.ndarray) -> np.ndarray:
    d = X_train.shape[1]
    k = max(1, int(round(frac * d)))
    if k >= d:
        return np.arange(d)
    # variance ranking (cheap, label-free)
    var = X_train.var(axis=0)
    return np.argsort(-var)[:k]


def apply_pipeline(spec: PipelineSpec, pre_stats, feat_idx, X: np.ndarray) -> jnp.ndarray:
    Xp = _apply_preproc(spec.preproc, pre_stats, X)
    return jnp.asarray(Xp[:, feat_idx], dtype=jnp.float32)


def _sample_specs(rng: np.random.Generator, n: int, families: Sequence[str]) -> List[PipelineSpec]:
    specs = []
    for _ in range(n):
        fam = families[rng.integers(len(families))]
        grid = FAMILIES[fam].hp_grid
        hp = tuple(sorted((k, v[rng.integers(len(v))]) for k, v in grid.items()))
        specs.append(
            PipelineSpec(
                preproc=PREPROCS[rng.integers(len(PREPROCS))],
                feature_frac=FEATURE_FRACS[rng.integers(len(FEATURE_FRACS))],
                family=fam,
                hp=hp,
            )
        )
    # dedup, keep order
    seen, out = set(), []
    for s in specs:
        key = (s.preproc, s.feature_frac, s.family, s.hp)
        if key not in seen:
            seen.add(key)
            out.append(s)
    return out


def automl_fit(
    X: np.ndarray,
    y: np.ndarray,
    *,
    config: AutoMLConfig = AutoMLConfig(),
    restrict_family: Optional[str] = None,
    X_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
) -> AutoMLResult:
    """Run the AutoML search.  Returns the best pipeline found.

    ``restrict_family`` implements the paper's restricted fine-tune pass."""
    t_start = time.perf_counter()
    X = np.asarray(X, dtype=np.float32)
    y = np.asarray(y)
    classes, y_enc = np.unique(y, return_inverse=True)
    n_classes = len(classes)
    rng = np.random.default_rng(config.seed)

    # train/val split
    N = X.shape[0]
    perm = rng.permutation(N)
    n_val = max(1, int(config.val_frac * N))
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    X_tr, y_tr = X[tr_idx], y_enc[tr_idx]
    X_val, y_val = X[val_idx], y_enc[val_idx]
    y_tr_j, y_val_j = jnp.asarray(y_tr), jnp.asarray(y_val)

    families = [restrict_family] if restrict_family else list(FAMILIES)
    n_seed_trials = config.n_trials if not restrict_family else max(4, config.n_trials // 4)
    specs = _sample_specs(rng, n_seed_trials, families)

    def out_of_budget() -> bool:
        return (
            config.time_budget_s is not None
            and time.perf_counter() - t_start > config.time_budget_s
        )

    # successive halving over epoch rungs
    live: List[tuple] = []       # (spec, val_acc, params, feat_idx, pre_stats)
    trials_log: List[tuple] = []
    n_done = 0
    pipe_cache: Dict[tuple, tuple] = {}

    current = specs
    for rung_i, epochs in enumerate(config.rungs):
        scored = []
        for spec in current:
            if out_of_budget() and scored:
                break
            ckey = (spec.preproc, spec.feature_frac)
            if ckey not in pipe_cache:
                stats = _fit_preproc(spec.preproc, X_tr)
                fidx = _select_features(spec.feature_frac, X_tr, y_tr)
                Xtr_p = apply_pipeline(spec, stats, fidx, X_tr)
                Xval_p = apply_pipeline(spec, stats, fidx, X_val)
                pipe_cache[ckey] = (stats, fidx, Xtr_p, Xval_p)
            stats, fidx, Xtr_p, Xval_p = pipe_cache[ckey]
            params = train_model(
                jax.random.key(config.seed + n_done),
                Xtr_p, y_tr_j, spec.family, n_classes, dict(spec.hp), epochs,
            )
            vacc = accuracy(params, Xval_p, y_val_j, spec.family)
            scored.append((spec, vacc, params, fidx, stats))
            trials_log.append((spec, vacc))
            n_done += 1
        scored.sort(key=lambda t: -t[1])
        live = scored
        keep = max(1, int(np.ceil(len(scored) * config.keep_frac)))
        current = [s for (s, *_rest) in scored[:keep]]
        if out_of_budget():
            break

    best_spec, best_vacc, best_params, best_fidx, best_stats = live[0]
    test_acc = None
    if X_test is not None:
        Xt = apply_pipeline(best_spec, best_stats, best_fidx, np.asarray(X_test, np.float32))
        yt = jnp.asarray(np.searchsorted(classes, np.asarray(y_test)))
        test_acc = accuracy(best_params, Xt, yt, best_spec.family)

    return AutoMLResult(
        spec=best_spec,
        params=best_params,
        val_acc=float(best_vacc),
        test_acc=test_acc,
        time_s=time.perf_counter() - t_start,
        n_trials=n_done,
        feat_idx=best_fidx,
        pre_stats=best_stats,
        trials=trials_log,
    )
