"""JAX-native model families for the AutoML substrate (DESIGN.md §5.4, §10.1).

Each family implements the tiny protocol (init / train / predict) on dense
``(N, d)`` float32 features and integer labels.  Training is jitted,
full-batch gradient descent with Adam (cost scales with N — exactly the
property SubStrat exploits), except the closed-form families (GNB, centroid).

``epochs`` is the successive-halving resource unit.  The full search-space
tables (families × HP grids) live in DESIGN.md §10.1.

Two execution paths consume these families:

- the sequential reference path (``train_model`` below, one trial at a time,
  used by ``automl/engine.py`` with ``backend="loop"``), and
- the batched cohort path (``automl/batched.py``), which pads every trial's
  params to the family's maximal shapes and advances the whole rung cohort
  under one ``jax.vmap``-ed Adam ``lax.scan`` (DESIGN.md §10.3).

``ModelFamily.shape_hps`` names the hyper-parameters that change the param
shapes or pytree structure (MLP ``depth`` changes the number of layers,
``width`` their sizes): the batched path sub-batches on those (padding MLP
widths only for small, dispatch-bound cohorts — ``batched.WIDTH_PAD_MAX_ROWS``)
and pads/stacks everything else (the feature axis, per-trial ``lr``/``l2``).
``init_keyless`` marks families whose init ignores the PRNG key (zero
init), letting the batched path build one broadcast init inside the jitted
cohort program.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from ..obs.jaxprof import note_trace

__all__ = ["FAMILIES", "ModelFamily", "adam_train", "train_model",
           "predict_model", "accuracy", "masked_loss", "masked_fit",
           "masked_accuracy", "CLASS_MASK_NEG"]


class ModelFamily(NamedTuple):
    name: str
    init: Callable[..., Any]
    loss: Callable[..., jax.Array] | None   # None => closed-form fit
    fit_closed: Callable[..., Any] | None
    predict: Callable[..., jax.Array]
    hp_grid: Dict[str, tuple]
    # HPs that change param shapes or pytree structure; the batched engine
    # sub-batches on these (DESIGN.md §10.3)
    shape_hps: tuple = ()
    # init ignores the PRNG key (e.g. zero init) — the batched engine may
    # broadcast a single init across the sub-batch
    init_keyless: bool = False


# ---------------------------------------------------------------------------
# gradient-trained families
# ---------------------------------------------------------------------------


def _xent(logits, y, n_classes):
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


# -- logistic regression -----------------------------------------------------

def _logreg_init(key, d, c, hp):
    return {"w": jnp.zeros((d, c)), "b": jnp.zeros((c,))}


def _logreg_loss(params, X, y, c, hp):
    logits = X @ params["w"] + params["b"]
    return _xent(logits, y, c) + hp["l2"] * jnp.sum(params["w"] ** 2)


def _logreg_predict(params, X):
    return X @ params["w"] + params["b"]


# -- MLP ----------------------------------------------------------------------

def _mlp_init(key, d, c, hp):
    width, depth = int(hp["width"]), int(hp["depth"])
    dims = [d] + [width] * depth + [c]
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, k in enumerate(keys):
        scale = (2.0 / dims[i]) ** 0.5
        layers.append(
            {"w": jax.random.normal(k, (dims[i], dims[i + 1])) * scale,
             "b": jnp.zeros((dims[i + 1],))}
        )
    return {"layers": layers}


def _mlp_forward(params, X):
    h = X
    for i, lyr in enumerate(params["layers"]):
        h = h @ lyr["w"] + lyr["b"]
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h


def _mlp_loss(params, X, y, c, hp):
    reg = sum(jnp.sum(l["w"] ** 2) for l in params["layers"])
    return _xent(_mlp_forward(params, X), y, c) + hp["l2"] * reg


# -- linear SVM (multi-class hinge) -------------------------------------------

def _svm_loss(params, X, y, c, hp):
    logits = X @ params["w"] + params["b"]
    correct = jnp.take_along_axis(logits, y[:, None], axis=1)
    margins = jnp.maximum(0.0, logits - correct + 1.0)
    margins = margins.at[jnp.arange(X.shape[0]), y].set(0.0)
    return margins.sum(axis=1).mean() + hp["l2"] * jnp.sum(params["w"] ** 2)


# ---------------------------------------------------------------------------
# closed-form families
# ---------------------------------------------------------------------------


def _gnb_fit(key, X, y, c, hp):
    eps = hp["var_smoothing"]
    onehot = jax.nn.one_hot(y, c)                      # (N, c)
    cnt = onehot.sum(0)[:, None]                       # (c, 1)
    mean = (onehot.T @ X) / jnp.maximum(cnt, 1.0)      # (c, d)
    sq = (onehot.T @ (X ** 2)) / jnp.maximum(cnt, 1.0)
    var = jnp.maximum(sq - mean ** 2, 0.0) + eps
    prior = jnp.log(jnp.maximum(cnt[:, 0] / X.shape[0], 1e-12))
    return {"mean": mean, "var": var, "prior": prior}


def _gnb_predict(params, X):
    # log N(x | mu, var) summed over dims + log prior
    mu, var, prior = params["mean"], params["var"], params["prior"]
    ll = -0.5 * (
        ((X[:, None, :] - mu[None]) ** 2) / var[None] + jnp.log(2 * jnp.pi * var)[None]
    ).sum(-1)
    return ll + prior[None]


def _centroid_fit(key, X, y, c, hp):
    onehot = jax.nn.one_hot(y, c)
    cnt = onehot.sum(0)[:, None]
    cent = (onehot.T @ X) / jnp.maximum(cnt, 1.0)
    overall = X.mean(0, keepdims=True)
    cent = overall + (cent - overall) * (1.0 - hp["shrinkage"])
    return {"cent": cent}


def _centroid_predict(params, X):
    d2 = ((X[:, None, :] - params["cent"][None]) ** 2).sum(-1)
    return -d2


FAMILIES: Dict[str, ModelFamily] = {
    "logreg": ModelFamily(
        "logreg", _logreg_init, _logreg_loss, None, _logreg_predict,
        {"lr": (0.3, 0.1, 0.03), "l2": (0.0, 1e-4, 1e-2)},
        init_keyless=True,
    ),
    "mlp": ModelFamily(
        "mlp", _mlp_init, _mlp_loss, None, _mlp_forward,
        {"lr": (0.01, 0.003, 0.001), "l2": (0.0, 1e-4), "width": (32, 64, 128), "depth": (1, 2)},
        shape_hps=("depth", "width"),
    ),
    "linear_svm": ModelFamily(
        "linear_svm", _logreg_init, _svm_loss, None, _logreg_predict,
        {"lr": (0.1, 0.03, 0.01), "l2": (1e-4, 1e-2)},
        init_keyless=True,
    ),
    "gnb": ModelFamily(
        "gnb", None, None, _gnb_fit, _gnb_predict,
        {"var_smoothing": (1e-9, 1e-6, 1e-3)},
    ),
    "centroid": ModelFamily(
        "centroid", None, None, _centroid_fit, _centroid_predict,
        {"shrinkage": (0.0, 0.2, 0.5)},
    ),
}


# ---------------------------------------------------------------------------
# masked counterparts for heterogeneous-shape cohort merging
# ---------------------------------------------------------------------------

# Additive class-mask constant: finite (no inf-inf NaNs) yet large enough
# that exp(CLASS_MASK_NEG - max_logit) underflows to exactly 0.0 in float32,
# so a masked class contributes exactly nothing to softmax/hinge/argmax and
# its logit receives exactly zero gradient.
CLASS_MASK_NEG = -1e30


def _xent_masked(logits, y, w):
    """Row-weighted cross-entropy: sum(w * nll) / sum(w).

    Padded rows enter as exact ``0.0`` terms of the sum, so the weighted
    mean equals the unpadded mean up to reduction order (DESIGN.md §12.3)."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return (nll * w).sum() / w.sum()


def masked_loss(family: str, params, X, y, w, cmask, c, hp):
    """Row/class-masked counterpart of ``FAMILIES[family].loss``.

    ``w`` is a (N,) 0/1 row-validity weight and ``cmask`` a (c,) additive
    class mask (0 for real classes, ``CLASS_MASK_NEG`` for padding).  With
    all-ones ``w`` and all-zeros ``cmask`` this computes the same quantity
    as the unmasked loss; with padding active, padded rows and classes are
    exactly inert — the heterogeneous-merge parity argument (§12.3)."""
    fam = FAMILIES[family]
    logits = fam.predict(params, X) + cmask[None, :]
    if family == "linear_svm":
        correct = jnp.take_along_axis(logits, y[:, None], axis=1)
        margins = jnp.maximum(0.0, logits - correct + 1.0)
        margins = margins.at[jnp.arange(X.shape[0]), y].set(0.0)
        data = (margins.sum(axis=1) * w).sum() / w.sum()
        reg = hp["l2"] * jnp.sum(params["w"] ** 2)
    elif family == "mlp":
        data = _xent_masked(logits, y, w)
        reg = hp["l2"] * sum(jnp.sum(l["w"] ** 2) for l in params["layers"])
    elif family == "logreg":
        data = _xent_masked(logits, y, w)
        reg = hp["l2"] * jnp.sum(params["w"] ** 2)
    else:
        raise ValueError(f"no masked loss for family {family!r}")
    return data + reg


def masked_fit(family: str, X, y, w, cmask, c, hp):
    """Row/class-masked counterpart of ``FAMILIES[family].fit_closed``:
    class statistics weight rows by ``w`` and the row count is ``w.sum()``;
    padded classes get ``CLASS_MASK_NEG`` priors (gnb) or are suppressed at
    prediction time via ``cmask`` (centroid)."""
    onehot = jax.nn.one_hot(y, c) * w[:, None]
    cnt = onehot.sum(0)[:, None]
    if family == "gnb":
        eps = hp["var_smoothing"]
        mean = (onehot.T @ X) / jnp.maximum(cnt, 1.0)
        sq = (onehot.T @ (X ** 2)) / jnp.maximum(cnt, 1.0)
        var = jnp.maximum(sq - mean ** 2, 0.0) + eps
        prior = jnp.log(jnp.maximum(cnt[:, 0] / w.sum(), 1e-12)) + cmask
        return {"mean": mean, "var": var, "prior": prior}
    if family == "centroid":
        cent = (onehot.T @ X) / jnp.maximum(cnt, 1.0)
        overall = (w[:, None] * X).sum(0, keepdims=True) / w.sum()
        cent = overall + (cent - overall) * (1.0 - hp["shrinkage"])
        return {"cent": cent}
    raise ValueError(f"no masked closed-form fit for family {family!r}")


def masked_accuracy(family: str, params, X, y, w, cmask):
    """Row-weighted accuracy with padded classes excluded from the argmax."""
    logits = FAMILIES[family].predict(params, X) + cmask[None, :]
    return ((jnp.argmax(logits, axis=1) == y) * w).sum() / w.sum()


# ---------------------------------------------------------------------------
# jitted training / eval drivers
# ---------------------------------------------------------------------------


def adam_train(grad_fn, params0, lr, epochs: int, n_steps=None):
    """Full-batch Adam ``lax.scan`` shared by both engine backends.

    This is the single definition of the training trajectory: the sequential
    path (``_train_gd``) and the batched cohort path
    (``batched._train_eval_cohort``) both call it, which is what keeps
    same-seed loop/batched parity bit-for-bit (DESIGN.md §10.4).  Works at
    trace level; ``lr`` may be a static float or a traced scalar.

    ``n_steps`` is the per-trial **step mask** of continuous rung batching
    (DESIGN.md §13.1): a traced scalar bounding how many of the ``epochs``
    scan steps actually update this trial.  Steps ``t >= n_steps`` compute
    (and discard) a gradient but select the previous ``(params, m, v)``
    carry unchanged, so a trial with 2 remaining epochs trains exactly 2
    steps inside a neighbor's 8-step scan — bit-identical to a solo
    ``epochs=n_steps`` run, since ``where(True, new, old)`` is exact and the
    bias-correction index ``t`` advances with the scan slot either way.
    ``n_steps=None`` keeps the unmasked trace (every step active)."""
    flat0, tree = jax.tree.flatten(params0)
    m0 = [jnp.zeros_like(x) for x in flat0]
    v0 = [jnp.zeros_like(x) for x in flat0]

    def step(carry, t):
        flat, m, v = carry
        g = jax.tree.leaves(grad_fn(jax.tree.unflatten(tree, flat)))
        m_n = [0.9 * mi + 0.1 * gi for mi, gi in zip(m, g)]
        v_n = [0.999 * vi + 0.001 * gi ** 2 for vi, gi in zip(v, g)]
        tcorr = t + 1
        flat_n = [
            fi - lr * (mi / (1 - 0.9 ** tcorr)) / (jnp.sqrt(vi / (1 - 0.999 ** tcorr)) + 1e-8)
            for fi, mi, vi in zip(flat, m_n, v_n)
        ]
        if n_steps is None:
            return (flat_n, m_n, v_n), None
        active = t < n_steps
        sel = lambda new, old: [jnp.where(active, a, b) for a, b in zip(new, old)]
        return (sel(flat_n, flat), sel(m_n, m), sel(v_n, v)), None

    (flat, _, _), _ = jax.lax.scan(step, (flat0, m0, v0), jnp.arange(epochs),
                                   unroll=8)
    return jax.tree.unflatten(tree, flat)


@functools.partial(jax.jit, static_argnames=("family", "c", "epochs", "hp_static"))
def _train_gd(key, X, y, family: str, c: int, epochs: int, hp_static: tuple):
    note_trace("models._train_gd")   # body runs only while tracing
    hp = dict(hp_static)
    fam = FAMILIES[family]
    params = fam.init(key, X.shape[1], c, hp)
    grad_fn = jax.grad(lambda p: fam.loss(p, X, y, c, hp))
    return adam_train(grad_fn, params, hp["lr"], epochs)


def train_model(key, X, y, family: str, n_classes: int, hp: dict, epochs: int):
    fam = FAMILIES[family]
    if fam.fit_closed is not None:
        return fam.fit_closed(key, X, y, n_classes, hp)
    return _train_gd(key, X, y, family, n_classes, epochs, tuple(sorted(hp.items())))


def predict_model(params, X, family: str):
    return FAMILIES[family].predict(params, X)


def accuracy(params, X, y, family: str) -> float:
    logits = predict_model(params, X, family)
    return float((jnp.argmax(logits, axis=1) == y).mean())
