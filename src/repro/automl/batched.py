"""Batched population trainer for the AutoML engine (DESIGN.md §10.3).

The sequential reference path (``engine._eval_rung_loop``) trains one trial
at a time: every distinct ``(family, hp)`` combination compiles its own XLA
program and pays a host round-trip per trial, and closed-form families are
fit eagerly op-by-op.  This module instead advances a whole
successive-halving rung cohort at once:

- **Pipelines as gather/scale ops.**  Each distinct ``(preproc, frac)``
  pair becomes one full-width data *variant*: the preprocessor's per-column
  affine map applied to all ``d`` columns, with non-selected columns zeroed
  in place (zero columns are inert for every family, so this matches the
  loop backend's column slicing).  Variants are stacked once into a cached
  ``(V, N, d)`` tensor; each trial carries a variant id and the jitted
  kernels gather its rows on device — no per-trial Python slicing.
- **Struct-of-arrays params.**  Trials are grouped by
  ``(family,) + shape_hps`` (HPs that change param shapes, e.g. MLP
  depth/width).  Width handling is regime-aware: small, dispatch-bound
  cohorts (``N <= WIDTH_PAD_MAX_ROWS``) pad MLP widths to the sub-batch max
  so all same-depth trials share one scan, while large, flop-bound cohorts
  split per width (padding there would inflate compute up to 16x).  Within
  a sub-batch, params stack leaf-wise into one pytree with a leading cohort
  axis: zero-init families build their init inside the jitted program; MLP
  inits at the loop backend's exact shapes with the loop backend's
  per-(trial, rung) keys, feature rows scattered into the full-width layout.
- **One dispatch per rung.**  Gradient families run one ``jax.vmap``-ed
  Adam ``lax.scan`` per sub-batch (the trajectory is ``models.adam_train``,
  shared with the loop backend; per-trial ``lr``/``l2`` as traced scalars);
  closed-form families one vmapped fit; accuracy evals are fused in.  With
  no wall-clock budget the whole rung is a single jitted program
  (``_eval_rung_fused``) and one host sync; with a budget active each
  sub-batch dispatches separately so the cutoff can land between them.

Promotion stays in ``engine.sh_promote`` (an on-device top-k mask) shared
with the loop backend; winner params are unpadded back to the sequential
shapes so downstream consumers are backend-agnostic (parity: §10.4).

**Cross-job cohort merge** (DESIGN.md §11.4): every trial is tagged with a
job slot and gathers its own job's data variant (``vids``), label vector
(``yids`` into a stacked ``(J, N)`` label tensor), and — for MLP — its own
job's ``(seed, trial_id, rung)`` init key.  ``eval_rung_cohorts`` exploits
this to fuse rung cohorts from *different* jobs with compatible data shapes
into one dispatch: sub-batches group by ``(family,) + shape_hps`` across
jobs, so eight 6-trial jobs cost one program launch instead of eight.
Merging changes dispatch granularity only — vmapped trials are independent,
so per-trial math is identical to single-job execution.

**Continuous rung batching** (DESIGN.md §13): ``eval_trial_megabatch``
drops the last merge precondition — cohorts no longer need to sit at the
same ``(rung_i, epochs)``.  Each trial additionally carries its rung cursor
(MLP init keys fold in the trial's *own* rung) and its remaining epoch
budget as a per-trial **step mask**: the shared Adam scan runs
``max(steps)`` slots and a trial with ``n_steps`` remaining freezes its
``(params, m, v)`` carry after ``n_steps`` of them
(``models.adam_train(n_steps=...)``) — the same inert-padding trick as the
row/class masks, applied to the time axis.  A 2-epoch trial and an 8-epoch
neighbor therefore share one jitted dispatch, which is what lets the
scheduler keep a single standing megabatch that trials join and leave as
they are promoted or culled, instead of lockstep ``(rung_i, epochs)``
buckets.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.jaxprof import note_trace
from .engine import (
    TrialCohort, _apply_preproc, _fit_preproc, _select_features, _trial_key,
)
from .models import (
    CLASS_MASK_NEG, FAMILIES, adam_train, masked_accuracy, masked_fit,
    masked_loss,
)

__all__ = ["eval_rung_batched", "eval_rung_cohorts", "eval_trial_megabatch"]


# ---------------------------------------------------------------------------
# pipeline variants: (preproc, feature_frac) -> full-width transformed data
# ---------------------------------------------------------------------------


def _variant(ctx, preproc: str, frac: float) -> int:
    """Ensure the (preproc, frac) variant exists; return its stable index.

    A variant keeps all ``d`` columns — non-selected ones zeroed — so every
    trial shares one array shape and the cohort kernels can gather by index.
    """
    cache = ctx["variant_cache"]
    vkey = (preproc, frac)
    if vkey not in cache:
        X_tr, y_tr, X_val = ctx["X_tr"], ctx["y_tr"], ctx["X_val"]
        stats = _fit_preproc(preproc, X_tr)
        fidx = _select_features(frac, X_tr, y_tr)
        mask = np.zeros((X_tr.shape[1],), np.float32)
        mask[fidx] = 1.0
        cache[vkey] = {
            "id": len(cache),
            "stats": stats,
            "fidx": fidx,
            "Xtr": _apply_preproc(preproc, stats, X_tr) * mask,
            "Xval": _apply_preproc(preproc, stats, X_val) * mask,
        }
        ctx.pop("variant_stack", None)   # invalidate the stacked tensor
    return cache[vkey]["id"]


def _variant_stack(ctx):
    """(V, N, d) / (V, Nval, d) stacked variants, rebuilt only on growth."""
    if "variant_stack" not in ctx:
        vs = sorted(ctx["variant_cache"].values(), key=lambda v: v["id"])
        ctx["variant_stack"] = (
            jnp.asarray(np.stack([v["Xtr"] for v in vs]), jnp.float32),
            jnp.asarray(np.stack([v["Xval"] for v in vs]), jnp.float32),
        )
    return ctx["variant_stack"]


def _concat_padded(parts, N_to: int, d_to: int):
    """Trace-level merge of per-job variant stacks into one (ΣV, N, d)
    tensor, zero-padding each part to the group-maximal shape.  Runs inside
    the jitted rung program so the padding fuses with the downstream
    gathers instead of materializing eagerly per rung."""
    if len(parts) == 1 and parts[0].shape[1] == N_to and parts[0].shape[2] == d_to:
        return parts[0]
    return jnp.concatenate([
        jnp.pad(x, ((0, 0), (0, N_to - x.shape[1]), (0, d_to - x.shape[2])))
        for x in parts])


# ---------------------------------------------------------------------------
# param padding / unpadding between loop-backend and full-width layouts
# ---------------------------------------------------------------------------


# Below this many training rows the cohort is dispatch-bound, so MLP widths
# pad to the sub-batch max (zero padding is gradient-inert — DESIGN.md §10.4)
# and all depths-equal trials share one scan.  Above it the cohort is
# flop-bound and width padding would inflate compute up to 16x, so widths
# split into separate sub-batches instead (DESIGN.md §10.3).
WIDTH_PAD_MAX_ROWS = 2048


def _unpad_linear(params, fidx, hp, c) -> dict:
    return {"w": params["w"][np.asarray(fidx)][:, :c], "b": params["b"][:c]}


def _unpad_mlp(params, fidx, hp, c) -> dict:
    width = int(hp["width"])
    layers, L = params["layers"], len(params["layers"])
    out = []
    for i, lyr in enumerate(layers):
        w, b = lyr["w"], lyr["b"]
        w = w[np.asarray(fidx)] if i == 0 else w[:width]
        if i < L - 1:            # hidden outputs may be width-padded
            w, b = w[:, :width], b[:width]
        else:                    # output classes may be class-padded (§12.3)
            w, b = w[:, :c], b[:c]
        out.append({"w": w, "b": b})
    return {"layers": out}


def _unpad_gnb(params, fidx, hp, c) -> dict:
    cols = np.asarray(fidx)
    return {"mean": params["mean"][:c, cols], "var": params["var"][:c, cols],
            "prior": params["prior"][:c]}


def _unpad_centroid(params, fidx, hp, c) -> dict:
    return {"cent": params["cent"][:c, np.asarray(fidx)]}


_UNPAD: Dict[str, Callable] = {
    "logreg": _unpad_linear, "linear_svm": _unpad_linear, "mlp": _unpad_mlp,
    "gnb": _unpad_gnb, "centroid": _unpad_centroid,
}


def _unpad_trial(family: str, params_b, j: int, fidx, hp, c: int):
    single = jax.tree.map(lambda x: x[j], params_b)
    return _UNPAD[family](single, fidx, hp, c)


# ---------------------------------------------------------------------------
# jitted cohort kernels: vmapped train+eval / fit+eval per family sub-batch
# ---------------------------------------------------------------------------


def _val_acc(fam, params, X, y):
    return (jnp.argmax(fam.predict(params, X), axis=1) == y).mean()


def _train_eval_cohort(fam, params0, Xall, Xall_val, Yall, Yall_val,
                       vids, yids, hp, c, epochs, masks=None, steps=None):
    """Trace-level core: vmapped Adam ``lax.scan`` fused with the
    validation-accuracy eval.  The trajectory is ``models.adam_train`` — the
    same definition the sequential backend runs — with the learning rate and
    regularisation arriving as traced per-trial scalars; each trial gathers
    its data variant from ``Xall`` and its job's labels from the stacked
    ``(J, N)`` label tensor ``Yall`` on device (single-job runs pass J=1).

    ``masks`` is None on exact-shape dispatches; a heterogeneous-shape merge
    passes ``(Wtr (J, N), Wval (J, Nval), Cmask (J, c))`` row/class padding
    masks and the trial trains through the masked loss (DESIGN.md §12.3).

    ``steps`` is None on uniform-rung dispatches; a cross-rung megabatch
    passes per-trial step budgets and each trial's scan carry freezes after
    its own ``steps[i]`` of the ``epochs`` scan slots (DESIGN.md §13.1)."""

    def one(p0, vid, yid, hp1, n_steps):
        X, y = Xall[vid], Yall[yid]
        if masks is None:
            grad_fn = jax.grad(lambda p: fam.loss(p, X, y, c, hp1))
        else:
            w, cm = masks[0][yid], masks[2][yid]
            grad_fn = jax.grad(
                lambda p: masked_loss(fam.name, p, X, y, w, cm, c, hp1))
        params = adam_train(grad_fn, p0, hp1["lr"], epochs, n_steps=n_steps)
        if masks is None:
            return params, _val_acc(fam, params, Xall_val[vid], Yall_val[yid])
        return params, masked_accuracy(
            fam.name, params, Xall_val[vid], Yall_val[yid],
            masks[1][yid], masks[2][yid])

    if steps is None:
        # keep the unmasked scan trace: one() closes over n_steps=None
        return jax.vmap(lambda p0, vid, yid, hp1: one(p0, vid, yid, hp1, None)
                        )(params0, vids, yids, hp)
    return jax.vmap(one)(params0, vids, yids, hp, steps)


def _keyless_cohort(family, T, Xall, Xall_val, Yall, Yall_val, vids, yids,
                    hp, c, epochs, masks=None, steps=None):
    """Zero-init families: the init happens inside the traced program."""
    fam = FAMILIES[family]
    p0 = fam.init(None, Xall.shape[2], c, {})
    params0 = jax.tree.map(lambda x: jnp.broadcast_to(x, (T,) + x.shape), p0)
    return _train_eval_cohort(fam, params0, Xall, Xall_val, Yall, Yall_val,
                              vids, yids, hp, c, epochs, masks, steps)


def _mlp_cohort(seeds, tids, rungs, fidxs, shapes, depth, wmax, d,
                Xall, Xall_val, Yall, Yall_val, vids, yids, hp, c, epochs,
                masks=None, steps=None):
    """MLP sub-batch: loop-identical per-trial init (same
    ``(seed, trial_id, rung)`` key, actual ``(k, width, c_job)`` shapes)
    scattered to the full-feature / ``wmax``-wide / ``c``-class layout,
    stacked, trained, and evaluated.  ``shapes[i] = (k, width, c_i)`` per
    trial; ``seeds`` is per-trial so merged cohorts derive each trial's key
    from its own job's seed, ``rungs`` is per-trial so a cross-rung
    megabatch folds each trial's *own* rung cursor into its key (§13), and
    ``c_i`` is the trial's own class count so a heterogeneous merge
    initializes exactly the solo shapes before class-padding.

    Padded rows/columns are zero and stay zero under Adam (zero input
    columns, ``relu'(0) = 0``; padded class logits are masked out of the
    softmax), so the active block trains exactly like the sequential path
    (DESIGN.md §10.4, §12.3)."""
    fam = FAMILIES["mlp"]
    plist = []
    for i, (k, width, ci) in enumerate(shapes):
        key = _trial_key(seeds[i], tids[i], rungs[i])  # loop-identical derivation
        p0 = fam.init(key, k, ci, {"width": width, "depth": depth})
        layers, L = p0["layers"], len(p0["layers"])
        out = []
        for li, lyr in enumerate(layers):
            w, b = lyr["w"], lyr["b"]
            if k == d and width == wmax and ci == c:
                out.append({"w": w, "b": b})
                continue
            in_dim = d if li == 0 else wmax
            out_dim = c if li == L - 1 else wmax
            buf = jnp.zeros((in_dim, out_dim), w.dtype)
            if li == 0:
                buf = buf.at[fidxs[i][:, None], jnp.arange(w.shape[1])[None, :]].set(w)
            else:
                buf = buf.at[: w.shape[0], : w.shape[1]].set(w)
            bbuf = jnp.zeros((out_dim,), b.dtype).at[: b.shape[0]].set(b)
            out.append({"w": buf, "b": bbuf})
        plist.append({"layers": out})
    params0 = jax.tree.map(lambda *xs: jnp.stack(xs), *plist)
    return _train_eval_cohort(fam, params0, Xall, Xall_val, Yall, Yall_val,
                              vids, yids, hp, c, epochs, masks, steps)


def _closed_cohort(family, Xall, Xall_val, Yall, Yall_val, vids, yids, hp, c,
                   masks=None):
    fam = FAMILIES[family]

    def one(vid, yid, hp1):
        X, y = Xall[vid], Yall[yid]
        if masks is None:
            params = fam.fit_closed(None, X, y, c, hp1)
            return params, _val_acc(fam, params, Xall_val[vid], Yall_val[yid])
        w, cm = masks[0][yid], masks[2][yid]
        params = masked_fit(family, X, y, w, cm, c, hp1)
        return params, masked_accuracy(
            family, params, Xall_val[vid], Yall_val[yid],
            masks[1][yid], cm)

    return jax.vmap(one)(vids, yids, hp)


class _GroupDesc(NamedTuple):
    """Hashable static descriptor of one family sub-batch (jit cache key)."""
    kind: str            # "closed" | "keyless" | "mlp"
    family: str
    T: int
    depth: int = 0
    wmax: int = 0
    shapes: tuple = ()   # mlp: ((k, width, c_trial), ...) per trial


def _run_group(desc, gin, Xall, Xall_val, Yall, Yall_val, c, d,
               epochs, masks=None):
    """Trace-level dispatch of one sub-batch; shared by the fused-rung and
    per-group (budget) paths, so both run identical math.

    Per-trial rung cursors (MLP key derivation) and step budgets ride in
    ``gin``: ``gin["rungs"]`` always for MLP sub-batches, ``gin["steps"]``
    only when the sub-batch mixes step budgets (uniform dispatches keep the
    unmasked scan trace — §13.1)."""
    steps = gin.get("steps")
    if desc.kind == "closed":
        return _closed_cohort(desc.family, Xall, Xall_val, Yall, Yall_val,
                              gin["vids"], gin["yids"], gin["hp"], c, masks)
    if desc.kind == "keyless":
        return _keyless_cohort(desc.family, desc.T, Xall, Xall_val, Yall,
                               Yall_val, gin["vids"], gin["yids"], gin["hp"],
                               c, epochs, masks, steps)
    return _mlp_cohort(gin["seeds"], gin["tids"], gin["rungs"], gin["fidxs"],
                       desc.shapes, desc.depth, desc.wmax, d, Xall, Xall_val,
                       Yall, Yall_val, gin["vids"], gin["yids"], gin["hp"],
                       c, epochs, masks, steps)


@functools.partial(jax.jit, static_argnames=("descs", "c", "d", "epochs"))
def _eval_rung_fused(ginputs, Xparts, Xval_parts, Yall, Yall_val,
                     masks, *, descs, c: int, d: int, epochs: int):
    """One dispatch for the whole rung: every family sub-batch trains and
    evaluates inside a single jitted program (used when no wall-clock budget
    needs mid-rung cutoffs).  With merged cohorts the sub-batches span jobs,
    so this is also one dispatch for the whole *job group*.

    ``Xparts``/``Xval_parts`` are tuples of per-job variant stacks, merged
    (and, when job shapes differ, zero-padded to the ``Yall`` row count /
    static ``d``) at trace level; ``masks`` is None for exact-shape
    dispatches, or the (Wtr, Wval, Cmask) padding tensors of a
    heterogeneous-shape merge (DESIGN.md §12.3).  ``epochs`` is the scan
    length — the max step budget across the dispatch; trials with fewer
    steps carry their budget in ``gin["steps"]`` (DESIGN.md §13.1)."""
    note_trace("batched._eval_rung_fused")   # body runs only while tracing
    Xall = _concat_padded(Xparts, Yall.shape[1], d)
    Xall_val = _concat_padded(Xval_parts, Yall_val.shape[1], d)
    return tuple(
        _run_group(desc, gin, Xall, Xall_val, Yall, Yall_val, c, d,
                   epochs, masks)
        for desc, gin in zip(descs, ginputs))


@functools.partial(jax.jit, static_argnames=("desc", "c", "d", "epochs"))
def _eval_group(gin, Xall, Xall_val, Yall, Yall_val,
                *, desc, c: int, d: int, epochs: int):
    """Single sub-batch dispatch — the budget path, so the engine can check
    the wall clock between sub-batches."""
    note_trace("batched._eval_group")
    return _run_group(desc, gin, Xall, Xall_val, Yall, Yall_val, c, d,
                      epochs)


# ---------------------------------------------------------------------------
# rung drivers: single-job and cross-job merged
# ---------------------------------------------------------------------------


class _TaggedTrial(NamedTuple):
    """One trial of a (possibly merged) rung dispatch."""
    job: int         # job slot = yid into the stacked (J, N) label tensor
    pos: int         # position in its job's cohort
    spec: object     # PipelineSpec
    tid: int         # trial id (PRNG key derivation)
    seed: int        # its job's AutoMLConfig.seed
    vid: int         # index into the merged variant stack
    c: int           # its job's class count (class-padding axis, §12.3)
    rung: int        # its own rung cursor (MLP key derivation, §13)
    steps: int       # its own epoch budget at that rung (step mask, §13.1)


def _group_subbatches(trials: List[_TaggedTrial], pad_widths: bool, variants,
                      epochs_max: int):
    """Group tagged trials by ``(family,) + shape_hps`` into dispatch jobs.

    Returns ``[(trial_indices, desc, gin)]`` — one static descriptor plus
    numpy inputs per sub-batch; numpy args are converted during the jit call,
    no eager dispatches.  Trials from different jobs land in the same
    sub-batch whenever family and shape HPs match — that is the cross-job
    merge.

    ``epochs_max`` is the dispatch-wide scan length.  Gradient sub-batches
    whose trials all train exactly ``epochs_max`` steps omit the ``steps``
    array so uniform (lockstep) dispatches keep the unmasked scan trace;
    mixed-budget sub-batches carry per-trial step masks (§13.1)."""
    groups: Dict[tuple, List[int]] = {}
    for t_i, t in enumerate(trials):
        hp = dict(t.spec.hp)
        fam = FAMILIES[t.spec.family]
        skip = ("width",) if pad_widths and t.spec.family == "mlp" else ()
        gkey = (t.spec.family,) + tuple(hp[k] for k in fam.shape_hps if k not in skip)
        groups.setdefault(gkey, []).append(t_i)

    subbatches: List[tuple] = []   # (trial_indices, desc, gin)
    for gkey, idxs in groups.items():
        family = gkey[0]
        fam = FAMILIES[family]
        gin = {
            "vids": np.asarray([trials[i].vid for i in idxs], np.int32),
            "yids": np.asarray([trials[i].job for i in idxs], np.int32),
            "hp": {k: np.asarray([dict(trials[i].spec.hp)[k] for i in idxs],
                                 np.float32)
                   for k in fam.hp_grid if k not in fam.shape_hps},
        }
        if fam.fit_closed is not None:
            # closed-form fits are epochs-independent: no step mask needed
            desc = _GroupDesc("closed", family, len(idxs))
            subbatches.append((idxs, desc, gin))
            continue
        if any(trials[i].steps != epochs_max for i in idxs):
            gin["steps"] = np.asarray([trials[i].steps for i in idxs],
                                      np.int32)
        if fam.init_keyless:
            desc = _GroupDesc("keyless", family, len(idxs))
        else:   # mlp
            hps = [dict(trials[i].spec.hp) for i in idxs]
            fidxs = tuple(np.asarray(variants[trials[i].vid]["fidx"])
                          for i in idxs)
            shapes = tuple((len(f), int(h["width"]), trials[i].c)
                           for f, h, i in zip(fidxs, hps, idxs))
            gin["tids"] = np.asarray([trials[i].tid for i in idxs], np.int32)
            gin["seeds"] = np.asarray([trials[i].seed for i in idxs], np.int32)
            gin["rungs"] = np.asarray([trials[i].rung for i in idxs], np.int32)
            gin["fidxs"] = fidxs
            desc = _GroupDesc("mlp", family, len(idxs),
                              depth=int(hps[0]["depth"]),
                              wmax=max(w for (_k, w, _c) in shapes),
                              shapes=shapes)
        subbatches.append((idxs, desc, gin))
    return subbatches


def _unpack_results(evaluated, trials, variants, collect_params):
    """One host sync for the whole dispatch; per-trial result tuples.

    Returns ``{trial_index: (val_acc, params, fidx, stats)}``."""
    all_vaccs = np.asarray(jnp.concatenate([v for (_i, v, _f, _pb) in evaluated]))
    results: Dict[int, tuple] = {}
    i = 0
    for idxs, _vaccs, family, params_b in evaluated:
        for j, t_i in enumerate(idxs):
            var = variants[trials[t_i].vid]
            if collect_params:
                # lazy: only the winner's params ever get sliced + unpadded
                # (the engine materializes callables on access)
                params = functools.partial(
                    _unpad_trial, family, params_b, j, var["fidx"],
                    dict(trials[t_i].spec.hp), trials[t_i].c)
            else:
                params = None
            results[t_i] = (float(all_vaccs[i]), params, var["fidx"], var["stats"])
            i += 1
    return results


def eval_rung_batched(cohort, tids, rung_i: int, epochs: int, ctx,
                      out_of_budget, collect_params: bool = True) -> Tuple[list, list]:
    """Evaluate one successive-halving rung as per-family sub-batches.

    Returns ``(scored, positions)`` where ``scored[i]`` is the loop-backend
    tuple ``(spec, val_acc, params, feat_idx, pre_stats)`` and
    ``positions[i]`` is its index into ``cohort``.  ``collect_params=False``
    (non-final rungs) skips the per-trial unpadding — promotion only needs
    accuracies.  Accuracies stay on device until one rung-level sync; when a
    wall-clock budget is active, each sub-batch blocks before the budget
    check so the cutoff sees real execution time."""
    d, c = ctx["X_tr"].shape[1], ctx["n_classes"]
    # dispatch-bound small cohorts pad MLP widths into one sub-batch;
    # flop-bound large ones split per width (see WIDTH_PAD_MAX_ROWS)
    pad_widths = ctx["X_tr"].shape[0] <= WIDTH_PAD_MAX_ROWS

    trials = [
        _TaggedTrial(0, pos, spec, int(tids[pos]), int(ctx["seed"]),
                     _variant(ctx, spec.preproc, spec.feature_frac), c,
                     rung_i, epochs)
        for pos, spec in enumerate(cohort)
    ]
    Xall_tr, Xall_val = _variant_stack(ctx)
    variants = {v["id"]: v for v in ctx["variant_cache"].values()}
    subbatches = _group_subbatches(trials, pad_widths, variants, epochs)
    budget_active = ctx.get("budget_active", False)

    common = (Xall_tr, Xall_val, ctx["y_tr_j"][None], ctx["y_val_j"][None])
    evaluated: List[tuple] = []   # (trial_indices, device vaccs, family, params_b)
    if budget_active:
        # one dispatch per sub-batch, blocking, so the wall-clock cutoff can
        # land between sub-batches
        for idxs, desc, gin in subbatches:
            if out_of_budget() and evaluated:
                break
            params_b, vaccs = _eval_group(gin, *common,
                                          desc=desc, c=c, d=d, epochs=epochs)
            jax.block_until_ready(vaccs)
            evaluated.append((idxs, vaccs, desc.family, params_b))
    else:
        # the whole rung is one jitted program
        outs = _eval_rung_fused(tuple(gin for (_i, _d, gin) in subbatches),
                                (Xall_tr,), (Xall_val,),
                                ctx["y_tr_j"][None], ctx["y_val_j"][None], None,
                                descs=tuple(d_ for (_i, d_, _g) in subbatches),
                                c=c, d=d, epochs=epochs)
        evaluated = [(idxs, vaccs, desc.family, params_b)
                     for (idxs, desc, _g), (params_b, vaccs)
                     in zip(subbatches, outs)]

    results = _unpack_results(evaluated, trials, variants, collect_params)
    # single-job: trial index == cohort position
    eval_pos = sorted(results)
    scored = [(cohort[p],) + results[p] for p in eval_pos]
    return scored, eval_pos


def eval_rung_cohorts(cohorts: List[TrialCohort],
                      collect_params=None) -> List[Tuple[list, list]]:
    """Cross-job rung merge: one fused dispatch for many jobs' cohorts.

    ``cohorts`` is a list of ``TrialCohort``s (``engine.
    search_trial_cohort``) sitting at the same ``(rung_i, epochs)``.  Every
    trial is tagged with its job slot, gathers its own job's data variant
    and label vector on device, and MLP trials derive init keys from their
    own job's ``(seed, trial_id, rung)``.  Returns per-job
    ``(scored, positions)`` pairs in input order.

    Two merge regimes (DESIGN.md §12.3):

    - **Exact** — all cohorts share ``(N_tr, N_val, d, n_classes)``: merging
      changes dispatch granularity only; per-trial math is bit-identical to
      single-job execution (the §11.4 parity argument).
    - **Padded** — shapes differ: every job's data variants are zero-padded
      to the group-maximal ``(N_max, d_max)``, labels to ``(J, N_max)``, and
      trials train through the row/class-masked losses
      (``models.masked_loss``), in which padded rows carry zero weight and
      padded class logits are additively masked out of softmax/hinge/argmax.
      Padding is inert up to floating-point reduction order, so results
      match solo execution to ~1e-6 rather than bit-exactly.

    ``collect_params=None`` collects params iff any cohort asks for them.
    No mid-rung time-budget support: the scheduler only merges jobs without
    ``time_budget_s`` (budgeted jobs run solo via ``eval_rung_batched``).
    """
    rung_i, epochs = cohorts[0].rung_i, cohorts[0].epochs
    for tc in cohorts[1:]:
        if tc.rung_i != rung_i or tc.epochs != epochs:
            raise ValueError("eval_rung_cohorts: cohorts must share "
                             "(rung_i, epochs)")
    return _eval_cohorts(cohorts, collect_params)


def eval_trial_megabatch(cohorts: List[TrialCohort],
                         collect_params=None) -> List[Tuple[list, list]]:
    """Continuous rung batching (DESIGN.md §13): one fused dispatch for
    cohorts at *different* rungs.

    Same merge semantics as ``eval_rung_cohorts`` — trials tag their job
    slot, data variant, labels, and (for MLP) init key — plus two per-trial
    degrees of freedom from ``TrialCohort.trial_rungs`` / ``trial_steps``:

    - each MLP trial folds its *own* rung cursor into its init key, so a
      rung-0 trial and a rung-2 trial in the same dispatch derive exactly
      the keys their solo runs would;
    - each gradient trial carries its own step budget; the shared Adam scan
      runs ``max(steps)`` slots and shorter trials freeze their carry after
      their own budget (``models.adam_train(n_steps=...)``).

    Both are inert-padding tricks: for the steps a trial actually takes the
    update math is bitwise the sequential path, so an exact-shape megabatch
    is bit-identical to lockstep dispatch and a hetero-shape one matches to
    ~1e-6 (the §12.3 reduction-order caveat).  Returns per-job
    ``(scored, positions)`` pairs in input order."""
    return _eval_cohorts(cohorts, collect_params)


def _eval_cohorts(cohorts: List[TrialCohort],
                  collect_params=None) -> List[Tuple[list, list]]:
    """Shared merge core for ``eval_rung_cohorts``/``eval_trial_megabatch``:
    tags trials (with their own rung cursor and step budget), pads shapes,
    groups sub-batches, and runs the fused dispatch."""
    if collect_params is None:
        collect_params = any(tc.collect for tc in cohorts)
    epochs = max(max(tc.trial_steps) for tc in cohorts)   # scan length
    shapes = [tc.shape for tc in cohorts]
    hetero = len(set(shapes)) > 1
    N_max = max(s[0] for s in shapes)
    Nval_max = max(s[1] for s in shapes)
    d = max(s[2] for s in shapes)
    c = max(s[3] for s in shapes)
    pad_widths = N_max <= WIDTH_PAD_MAX_ROWS

    # register every trial's variant in its own job's cache first (caches
    # persist across rungs), then offset local variant ids into one merged
    # stack: merged vid = job's offset + local vid
    local = []
    for slot, tc in enumerate(cohorts):
        rungs, steps = tc.trial_rungs, tc.trial_steps
        for pos, spec in enumerate(tc.specs):
            lvid = _variant(tc.ctx, spec.preproc, spec.feature_frac)
            local.append((slot, pos, spec, int(tc.tids[pos]),
                          int(tc.ctx["seed"]), lvid,
                          int(rungs[pos]), int(steps[pos])))
    offsets = np.concatenate([[0], np.cumsum(
        [len(tc.ctx["variant_cache"]) for tc in cohorts])])
    trials = [_TaggedTrial(slot, pos, spec, tid, seed,
                           int(offsets[slot]) + lvid,
                           int(cohorts[slot].ctx["n_classes"]),
                           rung, nsteps)
              for (slot, pos, spec, tid, seed, lvid, rung, nsteps) in local]

    stacks = [_variant_stack(tc.ctx) for tc in cohorts]
    if hetero:
        # per-job stacks go into the fused program unpadded — the trace
        # zero-pads them to the group-maximal shape (``_concat_padded``);
        # labels/masks are host numpy, transferred once inside the jit call.
        # The masks make the padding exactly inert (see module docstring).
        Yall_tr = np.stack([
            np.pad(tc.ctx["y_tr"], (0, N_max - tc.ctx["y_tr"].shape[0]))
            for tc in cohorts])
        Yall_val = np.stack([
            np.pad(tc.ctx["y_val"], (0, Nval_max - tc.ctx["y_val"].shape[0]))
            for tc in cohorts])
        masks = (
            np.stack([(np.arange(N_max) < s[0]).astype(np.float32)
                      for s in shapes]),
            np.stack([(np.arange(Nval_max) < s[1]).astype(np.float32)
                      for s in shapes]),
            np.stack([np.where(np.arange(c) < s[3], 0.0, CLASS_MASK_NEG)
                      .astype(np.float32) for s in shapes]),
        )
    else:
        Yall_tr = jnp.stack([tc.ctx["y_tr_j"] for tc in cohorts])
        Yall_val = jnp.stack([tc.ctx["y_val_j"] for tc in cohorts])
        masks = None
    variants = {}
    for slot, tc in enumerate(cohorts):
        for v in tc.ctx["variant_cache"].values():
            variants[int(offsets[slot]) + v["id"]] = v

    subbatches = _group_subbatches(trials, pad_widths, variants, epochs)
    outs = _eval_rung_fused(tuple(gin for (_i, _d, gin) in subbatches),
                            tuple(s[0] for s in stacks),
                            tuple(s[1] for s in stacks),
                            Yall_tr, Yall_val, masks,
                            descs=tuple(d_ for (_i, d_, _g) in subbatches),
                            c=c, d=d, epochs=epochs)
    evaluated = [(idxs, vaccs, desc.family, params_b)
                 for (idxs, desc, _g), (params_b, vaccs)
                 in zip(subbatches, outs)]
    results = _unpack_results(evaluated, trials, variants, collect_params)

    per_job: List[Tuple[list, list]] = []
    for slot, tc in enumerate(cohorts):
        idxs = [i for i in sorted(results) if trials[i].job == slot]
        scored = [(tc.specs[trials[i].pos],) + results[i] for i in idxs]
        per_job.append((scored, [trials[i].pos for i in idxs]))
    return per_job
