"""Synthetic tabular dataset generation mirroring the paper's 10 datasets.

The paper uses Kaggle/UCI downloads (Table 2).  This environment is offline,
so we generate datasets with the *same shapes* and controllable signal:
class-conditional Gaussian clusters for continuous features, class-correlated
multinomials for categorical features, plus pure-noise distractor columns.
The benchmark harness treats these exactly like the paper treats its corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = ["DatasetSpec", "PAPER_DATASETS", "make_dataset", "train_test_split"]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    domain: str
    n_rows: int
    n_cols: int                  # feature columns (paper counts incl. target)
    n_classes: int = 2
    frac_categorical: float = 0.4
    frac_informative: float = 0.5
    noise: float = 1.0
    seed: int = 0


# Table 2 of the paper (col counts there include the target column).
PAPER_DATASETS: Dict[str, DatasetSpec] = {
    "D1": DatasetSpec("D1", "flight service review", 129880, 22, 2, seed=1),
    "D2": DatasetSpec("D2", "signal processing", 15300, 4, 3, seed=2),
    "D3": DatasetSpec("D3", "car insurance", 10000, 17, 2, seed=3),
    "D4": DatasetSpec("D4", "mushroom classification", 8124, 22, 2,
                      frac_categorical=1.0, seed=4),
    "D5": DatasetSpec("D5", "air quality", 57660, 6, 4, seed=5),
    "D6": DatasetSpec("D6", "bike demand", 17415, 8, 3, seed=6),
    "D7": DatasetSpec("D7", "lead generation form", 46608, 14, 2, seed=7),
    "D8": DatasetSpec("D8", "myocardial infarction", 1700, 122, 2,
                      frac_informative=0.25, seed=8),
    "D9": DatasetSpec("D9", "heart disease", 79540, 6, 2, seed=9),
    "D10": DatasetSpec("D10", "poker matches", 1000000, 14, 4,
                       frac_categorical=0.8, seed=10),
}


def make_dataset(spec: DatasetSpec, scale: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
    """Generate (X, y).  ``scale`` shrinks row count (CPU-friendly benches)."""
    rng = np.random.default_rng(spec.seed)
    N = max(64, int(spec.n_rows * scale))
    M = spec.n_cols
    n_cat = int(round(spec.frac_categorical * M))
    n_info = max(1, int(round(spec.frac_informative * M)))
    info_cols = rng.permutation(M)[:n_info]
    info = np.zeros(M, dtype=bool)
    info[info_cols] = True

    y = rng.integers(0, spec.n_classes, N)
    X = np.empty((N, M), dtype=np.float32)
    # per-class means for informative continuous features
    class_means = rng.normal(0.0, 2.0, (spec.n_classes, M))
    for j in range(M):
        if j < n_cat:
            k = int(rng.integers(2, 12))  # cardinality
            if info[j]:
                # class-correlated categorical: per-class multinomial
                probs = rng.dirichlet(np.ones(k) * 0.6, spec.n_classes)
                u = rng.random(N)
                cdf = probs.cumsum(axis=1)
                X[:, j] = (u[:, None] < cdf[y]).argmax(axis=1)
            else:
                X[:, j] = rng.integers(0, k, N)
        else:
            mu = class_means[y, j] if info[j] else 0.0
            X[:, j] = mu + rng.normal(0.0, spec.noise, N)
    return X, y


def train_test_split(X, y, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    N = len(y)
    perm = rng.permutation(N)
    n_test = max(1, int(test_frac * N))
    te, tr = perm[:n_test], perm[n_test:]
    return X[tr], y[tr], X[te], y[te]
