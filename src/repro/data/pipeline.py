"""Deterministic, resumable, sharded LM data pipeline — with SubStrat's
measure-preserving subset selection as a first-class corpus operation.

* ``SyntheticCorpus``: deterministic Zipf-ish token corpus (seeded, lazy).
* ``ShardedLoader``: host-sharded batches; ``state()``/``restore()`` make it
  resumable; shard assignment is recomputed per step from the alive-host
  set (straggler/failure rebalancing — distributed/fault.assign_shards).
* ``select_corpus_subset``: Gen-DST over the (sequences × position-buckets)
  code matrix — picks an entropy-preserving subset of *sequences* to run
  cheap hyper-parameter searches on (the LM-scale analogue of the paper's
  DST; DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.gen_dst import GenDSTConfig, gen_dst
from ..core.measures import CodedDataset
from ..distributed.fault import assign_shards

__all__ = ["SyntheticCorpus", "ShardedLoader", "select_corpus_subset",
           "corpus_to_coded"]


class SyntheticCorpus:
    """Deterministic synthetic corpus: (n_seqs, seq_len) int32, lazy rows.

    Sequences are drawn from per-sequence topic distributions over a Zipfian
    vocabulary — different rows have genuinely different entropy profiles,
    which is what Gen-DST selects over."""

    def __init__(self, n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
                 n_topics: int = 16):
        self.n_seqs, self.seq_len, self.vocab, self.seed = n_seqs, seq_len, vocab, seed
        self.n_topics = n_topics
        base = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        zipf = 1.0 / ranks ** 1.1
        self._topic_probs = np.stack([
            np.roll(zipf, int(base.integers(0, vocab))) for _ in range(n_topics)
        ])
        self._topic_probs /= self._topic_probs.sum(axis=1, keepdims=True)

    def rows(self, idx: np.ndarray) -> np.ndarray:
        out = np.empty((len(idx), self.seq_len), np.int32)
        for j, i in enumerate(np.asarray(idx)):
            rng = np.random.default_rng(self.seed * 1_000_003 + int(i))
            topic = int(rng.integers(0, self.n_topics))
            out[j] = rng.choice(
                self.vocab, size=self.seq_len, p=self._topic_probs[topic]
            ).astype(np.int32)
        return out

    def __len__(self):
        return self.n_seqs


@dataclasses.dataclass
class LoaderState:
    step: int


class ShardedLoader:
    """Deterministic global-batch loader sharded across hosts.

    Every host computes the same global permutation; each takes the slice
    assigned by ``assign_shards(step, alive_hosts)`` — a dead/straggling
    host's slice migrates to survivors with no coordination."""

    def __init__(self, corpus: SyntheticCorpus, global_batch: int,
                 n_hosts: int = 1, host_id: int = 0, seed: int = 0,
                 subset: Optional[np.ndarray] = None):
        self.corpus = corpus
        self.global_batch = global_batch
        self.n_hosts, self.host_id, self.seed = n_hosts, host_id, seed
        self.pool = np.arange(len(corpus)) if subset is None else np.asarray(subset)
        self._step = 0

    def _global_indices(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed + step)
        return rng.choice(self.pool, size=self.global_batch, replace=len(self.pool) < self.global_batch)

    def next(self, alive_hosts: Optional[Sequence[int]] = None) -> Dict[str, np.ndarray]:
        alive = list(range(self.n_hosts)) if alive_hosts is None else list(alive_hosts)
        gidx = self._global_indices(self._step)
        shard_of = assign_shards(self.n_hosts, alive, self.n_hosts)
        mine = [s for s, h in shard_of.items() if h == self.host_id]
        per = self.global_batch // self.n_hosts
        rows = np.concatenate([gidx[s * per:(s + 1) * per] for s in mine]) if mine \
            else np.empty((0,), np.int64)
        toks = self.corpus.rows(rows)
        self._step += 1
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}

    def state(self) -> LoaderState:
        return LoaderState(self._step)

    def restore(self, st: LoaderState):
        self._step = st.step


def corpus_to_coded(
    corpus: SyntheticCorpus,
    *,
    n_position_buckets: int = 32,
    code_bins: int = 256,
    sample_rows: Optional[int] = None,
    seed: int = 0,
) -> Tuple[CodedDataset, np.ndarray]:
    """Build the (sequences × position-buckets) code matrix for Gen-DST.

    Column j = the token at a representative position of bucket j, coded by
    ``id % code_bins`` (order-preserving enough for frequency entropy).
    Returns (CodedDataset, row_ids) — row_ids maps code-matrix rows back to
    corpus sequence ids when subsampling."""
    n = len(corpus)
    if sample_rows is not None and sample_rows < n:
        rng = np.random.default_rng(seed)
        row_ids = np.sort(rng.choice(n, sample_rows, replace=False))
    else:
        row_ids = np.arange(n)
    toks = corpus.rows(row_ids)                                 # (R, S)
    S = toks.shape[1]
    cols = np.linspace(0, S - 1, n_position_buckets).astype(int)
    codes = (toks[:, cols] % code_bins).astype(np.int32)
    return CodedDataset(
        codes=jnp.asarray(codes),
        values=jnp.asarray(codes, jnp.float32),
        n_bins=jnp.full((codes.shape[1],), code_bins, jnp.int32),
        target_col=codes.shape[1] - 1,
        max_bins=code_bins,
    ), row_ids


def select_corpus_subset(
    corpus: SyntheticCorpus,
    n_subset: int,
    *,
    key: Optional[jax.Array] = None,
    cfg: GenDSTConfig = GenDSTConfig(),
    n_position_buckets: int = 32,
    sample_rows: Optional[int] = 8192,
) -> np.ndarray:
    """Entropy-preserving subset of sequence ids (SubStrat step 1 at LM scale)."""
    key = jax.random.key(0) if key is None else key
    coded, row_ids = corpus_to_coded(
        corpus, n_position_buckets=n_position_buckets, sample_rows=sample_rows
    )
    res = gen_dst(key, coded, n=min(n_subset, len(row_ids)),
                  m=max(2, n_position_buckets // 4), cfg=cfg)
    return row_ids[np.asarray(jax.device_get(res.row_idx))]
