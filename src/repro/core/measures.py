"""Dataset measures for measure-preserving data subsets (SubStrat §3.1).

The paper's primary measure is *dataset entropy* (Def. 3.4): the mean, over
columns, of the Shannon entropy (log2) of each column's empirical value
distribution.  (The formula as printed in the paper is notationally sloppy;
the worked Example 3.5 pins the intended semantics to standard per-column
Shannon entropy, which we match to 3 decimal places in tests.)

All entropy computation operates on *factorized* datasets: every column is
mapped once, up front, to dense integer codes in ``[0, n_bins_j)``.
Categorical / discrete columns keep exact value identity (paper-faithful);
continuous columns are quantile-binned to at most ``max_bins`` codes (see
DESIGN.md §5.1 — Def. 3.4 is degenerate on unrepeated floats).

Layout conventions (the ONE authoritative statement — every ``B``/histogram
docstring in this repo defers here)
---------------------------------------------------------------------------
``codes``   : (N, M) int32 — per-cell code, column j's codes in
              ``[0, n_bins[j])``.
``n_bins``  : (M,)  int32 — number of distinct codes per column.
``B``       : static int — shared histogram width, ``B >= max(n_bins)``.
              Histograms are (M, B) with one row per column.  Bins
              ``b >= n_bins[j]`` are *padding*: no code ever lands there, so
              their count is exactly zero, they carry zero probability mass,
              and they contribute 0 to every entropy sum.  This is what lets
              all M columns (and, in Gen-DST, all candidates) share one
              fixed-shape histogram tensor regardless of per-column
              cardinality.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.jaxprof import note_trace

__all__ = [
    "CodedDataset",
    "factorize",
    "column_counts",
    "column_entropy_from_counts",
    "column_entropy",
    "dataset_entropy",
    "subset_counts",
    "subset_entropy",
    "full_column_entropy",
    "measure_pnorm",
    "measure_mean_correlation",
    "measure_coeff_variation",
    "MEASURES",
]


class CodedDataset(NamedTuple):
    """A factorized dataset ready for entropy computation.

    ``values`` keeps the raw (float) matrix for measures other than entropy
    and for downstream AutoML training; ``codes`` drives the entropy measure.
    """

    codes: jax.Array          # (N, M) int32
    values: jax.Array         # (N, M) float32 (raw, un-normalized)
    n_bins: jax.Array         # (M,) int32
    target_col: int           # index of the target column (always in DSTs)
    max_bins: int             # histogram width B (see module docstring)

    @property
    def num_rows(self) -> int:
        return self.codes.shape[0]

    @property
    def num_cols(self) -> int:
        return self.codes.shape[1]


def factorize(
    X: np.ndarray,
    y: Optional[np.ndarray] = None,
    *,
    max_bins: int = 256,
    categorical_threshold: int = 64,
) -> CodedDataset:
    """Factorize a raw matrix (optionally with a target column) to codes.

    Columns with <= ``categorical_threshold`` distinct values keep exact value
    identity (one code per distinct value).  Denser columns are quantile-
    binned to ``max_bins`` codes.  The target column ``y`` (if given) is
    appended as the last column and is always treated as categorical.
    """
    X = np.asarray(X)
    cols = [np.asarray(X[:, j]) for j in range(X.shape[1])]
    if y is not None:
        cols.append(np.asarray(y))
    N = X.shape[0]
    codes = np.empty((N, len(cols)), dtype=np.int32)
    n_bins = np.empty((len(cols),), dtype=np.int32)
    values = np.empty((N, len(cols)), dtype=np.float32)
    for j, col in enumerate(cols):
        colf = col.astype(np.float64)
        values[:, j] = colf.astype(np.float32)
        uniq, inv = np.unique(colf, return_inverse=True)
        if len(uniq) <= max(categorical_threshold, 2) or (
            y is not None and j == len(cols) - 1
        ):
            codes[:, j] = inv.astype(np.int32)
            n_bins[j] = len(uniq)
        else:
            # quantile binning to at most max_bins codes
            qs = np.quantile(colf, np.linspace(0.0, 1.0, max_bins + 1)[1:-1])
            binned = np.searchsorted(qs, colf, side="right")
            # re-densify (some quantile bins may be empty)
            uniq_b, inv_b = np.unique(binned, return_inverse=True)
            codes[:, j] = inv_b.astype(np.int32)
            n_bins[j] = len(uniq_b)
    B = int(max(int(n_bins.max()), 2))
    return CodedDataset(
        codes=jnp.asarray(codes),
        values=jnp.asarray(values),
        n_bins=jnp.asarray(n_bins),
        target_col=len(cols) - 1 if y is not None else X.shape[1] - 1,
        max_bins=B,
    )


# ---------------------------------------------------------------------------
# Histogram + entropy primitives (pure jnp; the Pallas kernel in
# repro/kernels/entropy mirrors subset_counts' masked-histogram semantics).
# ---------------------------------------------------------------------------


def column_counts(codes: jax.Array, B: int, weights: Optional[jax.Array] = None) -> jax.Array:
    """Per-column histogram via flat scatter-add.

    codes: (n, M) int32;  weights: optional (n,) f32 row weights.
    Returns (M, B) float32 counts.
    """
    n, M = codes.shape
    flat = (codes + jnp.arange(M, dtype=codes.dtype)[None, :] * B).ravel()
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    w = jnp.broadcast_to(w[:, None], (n, M)).ravel()
    counts = jnp.zeros((M * B,), jnp.float32).at[flat].add(w)
    return counts.reshape(M, B)


def column_entropy_from_counts(counts: jax.Array) -> jax.Array:
    """Shannon entropy (log2) per column from (M, B) counts. Zero-safe."""
    total = jnp.maximum(counts.sum(axis=-1, keepdims=True), 1e-12)
    p = counts / total
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0), axis=-1)
    return h  # (M,)


def column_entropy(codes: jax.Array, B: int, weights: Optional[jax.Array] = None) -> jax.Array:
    return column_entropy_from_counts(column_counts(codes, B, weights))


def dataset_entropy(
    codes: jax.Array,
    B: int,
    col_mask: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """H(D) (Def. 3.4): mean over (selected) columns of column entropy."""
    h = column_entropy(codes, B, weights)
    if col_mask is None:
        return h.mean()
    cm = col_mask.astype(jnp.float32)
    return jnp.sum(h * cm) / jnp.maximum(cm.sum(), 1.0)


@functools.partial(jax.jit, static_argnames=("B", "chunk"))
def full_column_entropy(codes: jax.Array, B: int, chunk: int = 65536) -> jax.Array:
    """Column entropy of the full dataset, chunked over rows (bounded memory).

    Used once per Gen-DST run to precompute the reference ``F(D)`` terms.
    """
    note_trace("measures.full_column_entropy")   # body runs only at trace
    N, M = codes.shape
    pad = (-N) % chunk
    padded = jnp.pad(codes, ((0, pad), (0, 0)))
    w = jnp.pad(jnp.ones((N,), jnp.float32), (0, pad))
    def body(acc, xs):
        c, wc = xs
        return acc + column_counts(c, B, wc), None
    counts, _ = jax.lax.scan(
        body,
        jnp.zeros((M, B), jnp.float32),
        (padded.reshape(-1, chunk, M), w.reshape(-1, chunk)),
    )
    return column_entropy_from_counts(counts)


def subset_counts(codes: jax.Array, row_idx: jax.Array, B: int) -> jax.Array:
    """Histogram of the rows indexed by ``row_idx`` (gather path; single host).

    codes: (N, M); row_idx: (n,) int32. Returns (M, B) counts.
    """
    sub = jnp.take(codes, row_idx, axis=0)  # (n, M)
    return column_counts(sub, B)


def subset_entropy(
    codes: jax.Array,
    row_idx: jax.Array,
    col_mask: jax.Array,
    B: int,
) -> jax.Array:
    """H(D[r, c]) for one candidate DST: rows by index, columns by mask."""
    h = column_entropy_from_counts(subset_counts(codes, row_idx, B))  # (M,)
    cm = col_mask.astype(jnp.float32)
    return jnp.sum(h * cm) / jnp.maximum(cm.sum(), 1.0)


# ---------------------------------------------------------------------------
# Alternative dataset measures (paper §3.1: "other possible dataset measures
# ... p-norm, mean-correlation, and coefficient of variation").  These run on
# the raw float values of the subset.
# ---------------------------------------------------------------------------


def _subset_values(values: jax.Array, row_idx: jax.Array,
                   col_mask: Optional[jax.Array]):
    sub = jnp.take(values, row_idx, axis=0)  # (n, M)
    # registry contract: col_mask=None means "all columns" — every measure
    # must accept fn(values, row_idx) without a mask
    cm = (jnp.ones((values.shape[1],), jnp.float32) if col_mask is None
          else col_mask.astype(jnp.float32))
    return sub, cm


def measure_pnorm(values, row_idx=None, col_mask=None, p: float = 2.0):
    """Mean per-column p-norm, normalized by row count (scale-comparable)."""
    if row_idx is None:
        sub = values
        cm = jnp.ones((values.shape[1],), jnp.float32) if col_mask is None else col_mask.astype(jnp.float32)
    else:
        sub, cm = _subset_values(values, row_idx, col_mask)
    n = sub.shape[0]
    norms = (jnp.sum(jnp.abs(sub) ** p, axis=0) / n) ** (1.0 / p)  # (M,)
    return jnp.sum(norms * cm) / jnp.maximum(cm.sum(), 1.0)


def measure_mean_correlation(values, row_idx=None, col_mask=None):
    """Mean absolute pairwise Pearson correlation among selected columns."""
    if row_idx is None:
        sub = values
        cm = jnp.ones((values.shape[1],), jnp.float32) if col_mask is None else col_mask.astype(jnp.float32)
    else:
        sub, cm = _subset_values(values, row_idx, col_mask)
    mu = sub.mean(axis=0, keepdims=True)
    sd = sub.std(axis=0, keepdims=True) + 1e-9
    z = (sub - mu) / sd
    corr = (z.T @ z) / sub.shape[0]  # (M, M)
    w = cm[:, None] * cm[None, :]
    w = w * (1.0 - jnp.eye(values.shape[1]))
    return jnp.sum(jnp.abs(corr) * w) / jnp.maximum(w.sum(), 1.0)


def measure_coeff_variation(values, row_idx=None, col_mask=None):
    """Mean per-column coefficient of variation sigma/|mu|."""
    if row_idx is None:
        sub = values
        cm = jnp.ones((values.shape[1],), jnp.float32) if col_mask is None else col_mask.astype(jnp.float32)
    else:
        sub, cm = _subset_values(values, row_idx, col_mask)
    mu = sub.mean(axis=0)
    sd = sub.std(axis=0)
    cv = sd / (jnp.abs(mu) + 1e-9)
    return jnp.sum(cv * cm) / jnp.maximum(cm.sum(), 1.0)


# Registry contract: ``MEASURES[name]`` is either
#   * a callable ``fn(values, row_idx=None, col_mask=None) -> scalar`` that
#     scores a (sub)dataset on raw float values — Gen-DST evaluates it per
#     candidate with ``fn(values, rows, col_mask)`` and the reference value
#     as ``fn(values)``; or
#   * ``None`` for "entropy", which is NOT computed through this generic
#     interface: entropy works on factorized codes, so Gen-DST routes it
#     through the histogram fast path (carried per-candidate counts +
#     kernels/entropy backends) instead of a values-based callable.  Code
#     dispatching on a measure name must special-case ``"entropy"`` before
#     indexing this dict.
MEASURES = {
    "entropy": None,
    "pnorm": measure_pnorm,
    "mean_correlation": measure_mean_correlation,
    "coeff_variation": measure_coeff_variation,
}
