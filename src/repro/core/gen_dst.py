"""Gen-DST (SubStrat Algorithm 1) — fully vectorized genetic algorithm in JAX.

Genome representation (DESIGN.md §5.2):
  * rows   : (phi, n) int32 index matrix (a candidate's row subset r).
  * columns: (phi, M) bool membership mask with exactly ``m`` True entries,
             the target column always pinned True (paper §3.3: the target
             column is inserted into every DST and cannot be mutated).

The whole GA — mutation, crossover, royalty-tournament selection, fitness —
runs on device under one ``lax.scan`` over generations: no host round trips.
Fitness is the paper's ``f(G) = -|F(D[r,c]) - F(D)|`` with F = dataset
entropy evaluated via masked histograms (see measures.py / kernels/entropy).

Fixed-shape set operations:
  * "choose k random members of a mask" and "refill a mask to size m" use
    rank-of-random-scores tricks (double argsort) — O(M log M), fixed shape.
  * row-set dedup after crossover sorts the child and replaces duplicate
    slots with fresh uniform indices (collision probability ~ n^2/N; a
    surviving duplicate only double-weights one row in the histogram).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .measures import (
    CodedDataset,
    column_entropy_from_counts,
    full_column_entropy,
    subset_counts,
    MEASURES,
)

__all__ = ["GenDSTConfig", "DSTResult", "gen_dst", "default_dst_size", "random_dst"]


class GenDSTConfig(NamedTuple):
    psi: int = 30          # generations
    phi: int = 100         # population size (must be even)
    xi: float = 0.025      # mutation probability per candidate
    alpha: float = 0.05    # royalty (elite) fraction
    p_rc: float = 0.9      # P(mutate/cross rows) vs columns
    measure: str = "entropy"


class DSTResult(NamedTuple):
    row_idx: jax.Array     # (n,) int32
    col_mask: jax.Array    # (M,) bool
    fitness: jax.Array     # scalar, = -|F(d) - F(D)|
    history: jax.Array     # (psi,) best fitness per generation
    f_ref: jax.Array       # F(D)


def default_dst_size(N: int, M: int) -> tuple[int, int]:
    """Paper default DST size: (sqrt(N), 0.25*M), clamped to the data."""
    n = max(2, min(N, int(round(float(N) ** 0.5))))
    m = max(2, min(M, int(round(0.25 * M))))
    return n, m


# ---------------------------------------------------------------------------
# fixed-shape mask utilities
# ---------------------------------------------------------------------------


def _rank_desc(scores: jax.Array) -> jax.Array:
    """rank[i] = position of scores[i] in descending order (0 = largest)."""
    order = jnp.argsort(-scores)
    return jnp.argsort(order)


def _sample_members(key, mask: jax.Array, k) -> jax.Array:
    """Random sub-mask with min(k, |mask|) True entries drawn from ``mask``.

    ``k`` may be a traced scalar."""
    scores = jax.random.uniform(key, mask.shape) - jnp.where(mask, 0.0, jnp.inf)
    return mask & (_rank_desc(scores) < k)


def _refill_to(key, mask: jax.Array, m, forbidden: Optional[jax.Array] = None) -> jax.Array:
    """Add random positions outside ``mask`` (and ``forbidden``) until |mask| = m."""
    deficit = m - mask.sum()
    blocked = mask if forbidden is None else (mask | forbidden)
    scores = jax.random.uniform(key, mask.shape) - jnp.where(blocked, jnp.inf, 0.0)
    add = (~blocked) & (_rank_desc(scores) < deficit)
    return mask | add


def _dedup_rows(key, rows: jax.Array, N: int) -> jax.Array:
    """Sort a row-index vector and replace duplicate slots with fresh indices."""
    s = jnp.sort(rows)
    dup = jnp.concatenate([jnp.zeros((1,), bool), s[1:] == s[:-1]])
    fresh = jax.random.randint(key, rows.shape, 0, N, dtype=rows.dtype)
    return jnp.where(dup, fresh, s)


# ---------------------------------------------------------------------------
# population init
# ---------------------------------------------------------------------------


def _init_population(key, N: int, M: int, n: int, m: int, phi: int, target: int):
    kr, kc, kd = jax.random.split(key, 3)
    rows = jax.random.randint(kr, (phi, n), 0, N, dtype=jnp.int32)
    rows = jax.vmap(_dedup_rows, in_axes=(0, 0, None))(
        jax.random.split(kd, phi), rows, N
    )
    tgt = jnp.zeros((M,), bool).at[target].set(True)
    def one_colmask(k):
        empty = jnp.zeros((M,), bool)
        return _refill_to(k, tgt, m, forbidden=empty) | tgt
    cols = jax.vmap(one_colmask)(jax.random.split(kc, phi))
    return rows, cols


# ---------------------------------------------------------------------------
# fitness
# ---------------------------------------------------------------------------


def _entropy_fitness(codes, B, f_ref, rows, cols):
    """Vectorized fitness over the population (entropy fast path)."""
    def one(r, cm):
        h = column_entropy_from_counts(subset_counts(codes, r, B))
        cmf = cm.astype(jnp.float32)
        f_d = jnp.sum(h * cmf) / jnp.maximum(cmf.sum(), 1.0)
        return -jnp.abs(f_d - f_ref)
    return jax.vmap(one)(rows, cols)


def _generic_fitness(values, measure_fn, f_ref, rows, cols):
    def one(r, cm):
        return -jnp.abs(measure_fn(values, r, cm) - f_ref)
    return jax.vmap(one)(rows, cols)


# ---------------------------------------------------------------------------
# GA operators
# ---------------------------------------------------------------------------


def _mutate(key, rows, cols, *, N, M, n, m, xi, p_rc, target):
    phi = rows.shape[0]
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    do_mut = jax.random.uniform(k1, (phi,)) < xi
    mut_rows = jax.random.uniform(k2, (phi,)) < p_rc

    # --- row mutation: replace one random slot with a fresh index -----------
    slot = jax.random.randint(k3, (phi,), 0, n)
    fresh = jax.random.randint(k4, (phi,), 0, N, dtype=rows.dtype)
    # skip if fresh already a member (keeps |r ∩ r'| = n-1 semantics cheaply)
    already = (rows == fresh[:, None]).any(axis=1)
    apply_row = do_mut & mut_rows & (~already)
    new_rows = rows.at[jnp.arange(phi), slot].set(
        jnp.where(apply_row, fresh, rows[jnp.arange(phi), slot])
    )

    # --- column mutation: swap one ON (non-target) for one OFF column -------
    tgt = jnp.zeros((M,), bool).at[target].set(True)
    def col_mut(k, cm):
        ka, kb = jax.random.split(k)
        off = _sample_members(ka, cm & (~tgt), 1)   # one member to drop
        on = _sample_members(kb, ~cm, 1)            # one non-member to add
        ok = (off.sum() == 1) & (on.sum() == 1)
        return jnp.where(ok, (cm & ~off) | on, cm)
    mutated_cols = jax.vmap(col_mut)(jax.random.split(k5, phi), cols)
    apply_col = (do_mut & (~mut_rows))[:, None]
    new_cols = jnp.where(apply_col, mutated_cols, cols)
    return new_rows, new_cols


def _crossover(key, rows, cols, *, N, M, n, m, p_rc, target):
    """Pairwise split-and-swap crossover over the whole population."""
    phi = rows.shape[0]
    half = phi // 2
    kp, kt, ks, kra, krb, kca, kcb, kfa, kfb, kda, kdb = jax.random.split(key, 11)

    perm = jax.random.permutation(kp, phi)
    ra, rb = rows[perm[:half]], rows[perm[half:]]
    ca, cb = cols[perm[:half]], cols[perm[half:]]

    cross_rows = jax.random.uniform(kt, (half,)) < p_rc

    # --- row crossover: child_ab = s rows of a + (n-s) rows of b ------------
    s_r = jax.random.randint(ks, (half,), 1, jnp.maximum(n, 2))
    pa = jax.vmap(lambda k, r: jax.random.permutation(k, r))(
        jax.random.split(kra, half), ra
    )
    pb = jax.vmap(lambda k, r: jax.random.permutation(k, r))(
        jax.random.split(krb, half), rb
    )
    take_a = jnp.arange(n)[None, :] < s_r[:, None]
    child_ab_rows = jnp.where(take_a, pa, pb)   # s from a, rest from b
    child_ba_rows = jnp.where(take_a, pb, pa)
    child_ab_rows = jax.vmap(_dedup_rows, in_axes=(0, 0, None))(
        jax.random.split(kda, half), child_ab_rows, N
    )
    child_ba_rows = jax.vmap(_dedup_rows, in_axes=(0, 0, None))(
        jax.random.split(kdb, half), child_ba_rows, N
    )

    # --- column crossover: union of s members of a and (m-s) of b, refill ---
    tgt = jnp.zeros((M,), bool).at[target].set(True)
    s_c = jax.random.randint(ks, (half,), 1, jnp.maximum(m - 1, 2))
    def col_child(k, kf, cma, cmb, s):
        k1, k2 = jax.random.split(k)
        u = _sample_members(k1, cma & ~tgt, s) | _sample_members(
            k2, cmb & ~tgt, m - 1 - s
        )
        u = u | tgt
        return _refill_to(kf, u, m)
    child_ab_cols = jax.vmap(col_child)(
        jax.random.split(kca, half), jax.random.split(kfa, half), ca, cb, s_c
    )
    child_ba_cols = jax.vmap(col_child)(
        jax.random.split(kcb, half), jax.random.split(kfb, half), cb, ca, s_c
    )

    # row-cross keeps own columns; col-cross keeps own rows (paper §3.3)
    ab_rows = jnp.where(cross_rows[:, None], child_ab_rows, ra)
    ba_rows = jnp.where(cross_rows[:, None], child_ba_rows, rb)
    ab_cols = jnp.where(cross_rows[:, None], ca, child_ab_cols)
    ba_cols = jnp.where(cross_rows[:, None], cb, child_ba_cols)

    new_rows = jnp.concatenate([ab_rows, ba_rows], axis=0)
    new_cols = jnp.concatenate([ab_cols, ba_cols], axis=0)
    return new_rows, new_cols


def _select(key, rows, cols, fitness, *, alpha):
    """Royalty tournament: keep top alpha*phi, sample the rest ∝ fitness."""
    phi = fitness.shape[0]
    n_elite = max(1, int(round(alpha * phi)))
    order = jnp.argsort(-fitness)
    elite = order[:n_elite]
    # fitness-proportional sampling on shifted fitness (fitness <= 0)
    w = fitness - fitness.min() + 1e-9
    drawn = jax.random.choice(key, phi, (phi - n_elite,), replace=True, p=w / w.sum())
    keep = jnp.concatenate([elite, drawn])
    return rows[keep], cols[keep]


# ---------------------------------------------------------------------------
# main entry point
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("n", "m", "cfg", "B", "target"),
)
def _gen_dst_jit(key, codes, values, n, m, cfg: GenDSTConfig, B, target):
    N, M = codes.shape
    if cfg.measure == "entropy":
        h_full = full_column_entropy(codes, B)
        f_ref = h_full.mean()
        fitness_fn = lambda r, c: _entropy_fitness(codes, B, f_ref, r, c)
    else:
        measure_fn = MEASURES[cfg.measure]
        f_ref = measure_fn(values)
        fitness_fn = lambda r, c: _generic_fitness(values, measure_fn, f_ref, r, c)

    k0, kloop = jax.random.split(key)
    rows, cols = _init_population(k0, N, M, n, m, cfg.phi, target)
    fit0 = fitness_fn(rows, cols)
    best0 = jnp.argmax(fit0)
    carry0 = (rows, cols, fit0[best0], rows[best0], cols[best0], kloop)

    def generation(carry, _):
        rows, cols, best_f, best_r, best_c, key = carry
        key, km, kx, ksel = jax.random.split(key, 4)
        rows2, cols2 = _mutate(
            km, rows, cols, N=N, M=M, n=n, m=m, xi=cfg.xi, p_rc=cfg.p_rc, target=target
        )
        rows2, cols2 = _crossover(
            kx, rows2, cols2, N=N, M=M, n=n, m=m, p_rc=cfg.p_rc, target=target
        )
        fit = fitness_fn(rows2, cols2)
        gbest = jnp.argmax(fit)
        better = fit[gbest] > best_f
        best_f = jnp.where(better, fit[gbest], best_f)
        best_r = jnp.where(better, rows2[gbest], best_r)
        best_c = jnp.where(better, cols2[gbest], best_c)
        rows3, cols3 = _select(ksel, rows2, cols2, fit, alpha=cfg.alpha)
        return (rows3, cols3, best_f, best_r, best_c, key), best_f

    carry, history = jax.lax.scan(generation, carry0, None, length=cfg.psi)
    _, _, best_f, best_r, best_c, _ = carry
    return best_r, best_c, best_f, history, f_ref


def gen_dst(
    key: jax.Array,
    coded: CodedDataset,
    n: Optional[int] = None,
    m: Optional[int] = None,
    cfg: GenDSTConfig = GenDSTConfig(),
) -> DSTResult:
    """Run Gen-DST on a factorized dataset; returns the best DST found."""
    N, M = coded.codes.shape
    dn, dm = default_dst_size(N, M)
    n = dn if n is None else min(n, N)
    m = dm if m is None else min(m, M)
    assert cfg.phi % 2 == 0, "population size must be even (pairwise crossover)"
    best_r, best_c, best_f, history, f_ref = _gen_dst_jit(
        key, coded.codes, coded.values, n, m, cfg, coded.max_bins, coded.target_col
    )
    return DSTResult(best_r, best_c, best_f, history, f_ref)


def random_dst(key, coded: CodedDataset, n: Optional[int] = None, m: Optional[int] = None):
    """A uniformly random DST (the paper's trivial baseline building block)."""
    N, M = coded.codes.shape
    dn, dm = default_dst_size(N, M)
    n = dn if n is None else min(n, N)
    m = dm if m is None else min(m, M)
    rows, cols = _init_population(key, N, M, n, m, 2, coded.target_col)
    return DSTResult(rows[0], cols[0], jnp.float32(jnp.nan), jnp.zeros((0,)), jnp.float32(jnp.nan))
