"""Gen-DST (SubStrat Algorithm 1) — fully vectorized genetic algorithm in JAX.

Genome representation (DESIGN.md §5.2):
  * rows   : (phi, n) int32 index matrix (a candidate's row subset r).
  * columns: (phi, M) bool membership mask with exactly ``m`` True entries,
             the target column always pinned True (paper §3.3: the target
             column is inserted into every DST and cannot be mutated).

The whole GA — mutation, crossover, royalty-tournament selection, fitness —
runs on device under one ``lax.scan`` over generations: no host round trips.
Fitness is the paper's ``f(G) = -|F(D[r,c]) - F(D)|`` with F = dataset
entropy evaluated via masked histograms (see measures.py / kernels/entropy).

Search-loop architecture (DESIGN.md §5.5):
  * Incremental fitness: each candidate's (M, B) count tensor rides in the
    scan carry.  A row mutation replaces exactly one row, so its histogram
    delta is one subtract + one add of a single row's codes — O(M) scatter
    work instead of an O(n*M) re-gather.  Column mutation/crossover never
    touches counts at all: counts cover all M columns, the column mask only
    reweights the entropy average.  Full recomputes happen only on
    row-crossover generations (``cross_every`` cadence) and route through
    ``kernels/entropy`` under the ``backend=`` switch ("jnp" scatter-add
    reference, or the Pallas MXU kernel).
  * Islands: ``num_islands`` independent sub-populations evolved under one
    vmap, with ring elite migration every ``migrate_every`` generations
    (each island's worst ``migrate_frac * phi`` candidates are replaced by
    its neighbour's best).  Multi-start search at no extra wall-clock depth.

Fixed-shape set operations:
  * "choose k random members of a mask" and "refill a mask to size m" use
    rank-of-random-scores tricks (double argsort) — O(M log M), fixed shape.
  * row-set dedup after crossover sorts the child and replaces duplicate
    slots with fresh uniform indices (collision probability ~ n^2/N; a
    surviving duplicate only double-weights one row in the histogram).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .measures import (
    CodedDataset,
    column_entropy_from_counts,
    full_column_entropy,
    subset_counts,
    MEASURES,
)
from ..kernels.entropy.ops import population_histogram, resolve_interpret
from ..kernels.gen_dst.ops import fused_delta_fitness
from ..obs.jaxprof import note_trace

__all__ = ["GenDSTConfig", "DSTResult", "gen_dst", "gen_dst_batch",
           "default_dst_size", "random_dst", "GEN_DST_BACKENDS"]

# full-recompute histogram / fused-generation execution backends
# (DESIGN.md §16.3): "jnp" is the bit-level oracle everywhere.
GEN_DST_BACKENDS = ("jnp", "pallas", "pallas_fused")


def _validate_cfg(cfg: "GenDSTConfig") -> None:
    """Shared solo/batched config validation: a bad config must fail fast
    identically on both paths instead of diverging batched-vs-solo."""
    assert cfg.phi % 2 == 0, "population size must be even (pairwise crossover)"
    assert cfg.num_islands >= 1 and cfg.cross_every >= 1 and cfg.migrate_every >= 1
    if cfg.backend not in GEN_DST_BACKENDS:
        raise ValueError(
            f"unknown Gen-DST backend {cfg.backend!r}; expected one of "
            f"{', '.join(GEN_DST_BACKENDS)}")


class GenDSTConfig(NamedTuple):
    psi: int = 30          # generations
    phi: int = 100         # population size PER ISLAND (must be even)
    xi: float = 0.025      # mutation probability per candidate
    alpha: float = 0.05    # royalty (elite) fraction
    p_rc: float = 0.9      # P(mutate/cross rows) vs columns
    measure: str = "entropy"
    # --- search-loop extensions (DESIGN.md §5.5, §16) ----------------------
    # execution backend: "jnp" (XLA reference, the bit-level oracle),
    # "pallas" (MXU histogram on full recomputes only), or "pallas_fused"
    # (the §16 kernel: delta-update + fitness fused into one VMEM-resident
    # launch per generation, MXU histogram on crossover recomputes)
    backend: str = "jnp"
    incremental: bool = True   # delta-update counts on mutation-only gens
    cross_every: int = 1   # crossover every k-th generation (1 = seed-faithful)
    num_islands: int = 1   # independent sub-populations (vmapped)
    migrate_every: int = 5     # generations between elite migrations
    migrate_frac: float = 0.1  # fraction of phi migrated per event


class DSTResult(NamedTuple):
    row_idx: jax.Array     # (n,) int32
    col_mask: jax.Array    # (M,) bool
    fitness: jax.Array     # scalar, = -|F(d) - F(D)|
    history: jax.Array     # (psi,) best fitness per generation
    f_ref: jax.Array       # F(D)


def default_dst_size(N: int, M: int) -> tuple[int, int]:
    """Paper default DST size: (sqrt(N), 0.25*M), clamped to the data."""
    n = max(2, min(N, int(round(float(N) ** 0.5))))
    m = max(2, min(M, int(round(0.25 * M))))
    return n, m


# ---------------------------------------------------------------------------
# fixed-shape mask utilities
# ---------------------------------------------------------------------------


def _rank_desc(scores: jax.Array) -> jax.Array:
    """rank[i] = position of scores[i] in descending order (0 = largest)."""
    order = jnp.argsort(-scores)
    return jnp.argsort(order)


def _sample_members(key, mask: jax.Array, k) -> jax.Array:
    """Random sub-mask with min(k, |mask|) True entries drawn from ``mask``.

    ``k`` may be a traced scalar."""
    scores = jax.random.uniform(key, mask.shape) - jnp.where(mask, 0.0, jnp.inf)
    return mask & (_rank_desc(scores) < k)


def _refill_to(key, mask: jax.Array, m, forbidden: Optional[jax.Array] = None) -> jax.Array:
    """Add random positions outside ``mask`` (and ``forbidden``) until |mask| = m."""
    deficit = m - mask.sum()
    blocked = mask if forbidden is None else (mask | forbidden)
    scores = jax.random.uniform(key, mask.shape) - jnp.where(blocked, jnp.inf, 0.0)
    add = (~blocked) & (_rank_desc(scores) < deficit)
    return mask | add


def _dedup_rows(key, rows: jax.Array, N: int) -> jax.Array:
    """Sort a row-index vector and replace duplicate slots with fresh indices."""
    s = jnp.sort(rows)
    dup = jnp.concatenate([jnp.zeros((1,), bool), s[1:] == s[:-1]])
    fresh = jax.random.randint(key, rows.shape, 0, N, dtype=rows.dtype)
    return jnp.where(dup, fresh, s)


# ---------------------------------------------------------------------------
# population init
# ---------------------------------------------------------------------------


def _init_population(key, N: int, M: int, n: int, m: int, phi: int, target: int):
    kr, kc, kd = jax.random.split(key, 3)
    rows = jax.random.randint(kr, (phi, n), 0, N, dtype=jnp.int32)
    rows = jax.vmap(_dedup_rows, in_axes=(0, 0, None))(
        jax.random.split(kd, phi), rows, N
    )
    tgt = jnp.zeros((M,), bool).at[target].set(True)
    def one_colmask(k):
        empty = jnp.zeros((M,), bool)
        return _refill_to(k, tgt, m, forbidden=empty) | tgt
    cols = jax.vmap(one_colmask)(jax.random.split(kc, phi))
    return rows, cols


# ---------------------------------------------------------------------------
# fitness
# ---------------------------------------------------------------------------


def _entropy_fitness(codes, B, f_ref, rows, cols):
    """Vectorized fitness over the population (gather-recompute path)."""
    def one(r, cm):
        h = column_entropy_from_counts(subset_counts(codes, r, B))
        cmf = cm.astype(jnp.float32)
        f_d = jnp.sum(h * cmf) / jnp.maximum(cmf.sum(), 1.0)
        return -jnp.abs(f_d - f_ref)
    return jax.vmap(one)(rows, cols)


def _counts_fitness(counts, cols, f_ref):
    """Fitness from carried per-candidate counts: (..., M, B) + (..., M)."""
    h = column_entropy_from_counts(counts)            # (..., M)
    cmf = cols.astype(jnp.float32)
    f_d = jnp.sum(h * cmf, axis=-1) / jnp.maximum(cmf.sum(axis=-1), 1.0)
    return -jnp.abs(f_d - f_ref)


def _generic_fitness(values, measure_fn, f_ref, rows, cols):
    def one(r, cm):
        return -jnp.abs(measure_fn(values, r, cm) - f_ref)
    return jax.vmap(one)(rows, cols)


def _population_counts(codes, rows, B, *, backend, interpret):
    """(..., phi, n) row indices -> (..., phi, M, B) per-candidate counts."""
    lead = rows.shape[:-1]
    n = rows.shape[-1]
    M = codes.shape[1]
    sub = jnp.take(codes, rows.reshape(-1, n), axis=0)        # (P, n, M)
    hist = population_histogram(sub, B, backend=backend, interpret=interpret)
    return hist.reshape(*lead, M, B)


def _row_delta(codes, counts, old_rows, new_rows, applied):
    """Delta-update per-candidate counts after a one-row mutation.

    counts: (phi, M, B); old_rows/new_rows: (phi,) row indices; applied:
    (phi,) bool — candidates whose mutation actually fired.  Subtracts the
    evicted row's one-hot contribution and adds the fresh row's.
    """
    oc = jnp.take(codes, old_rows, axis=0)        # (phi, M)
    nc = jnp.take(codes, new_rows, axis=0)
    w = applied.astype(jnp.float32)[:, None]      # (phi, 1)
    phi, M = oc.shape
    ai = jnp.arange(phi)[:, None]
    aj = jnp.arange(M)[None, :]
    counts = counts.at[ai, aj, oc].add(-w)
    counts = counts.at[ai, aj, nc].add(w)
    return counts


# ---------------------------------------------------------------------------
# GA operators
# ---------------------------------------------------------------------------


def _mutate_core(key, rows, cols, *, N, M, n, m, xi, p_rc, target):
    """Mutation + the bookkeeping incremental fitness needs.

    Returns (new_rows, new_cols, applied, old_vals, fresh): ``applied`` marks
    candidates whose ROW mutation fired; ``old_vals``/``fresh`` are the
    evicted/inserted row indices (ignored where not applied).
    """
    phi = rows.shape[0]
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    do_mut = jax.random.uniform(k1, (phi,)) < xi
    mut_rows = jax.random.uniform(k2, (phi,)) < p_rc

    # --- row mutation: replace one random slot with a fresh index -----------
    slot = jax.random.randint(k3, (phi,), 0, n)
    fresh = jax.random.randint(k4, (phi,), 0, N, dtype=rows.dtype)
    # skip if fresh already a member (keeps |r ∩ r'| = n-1 semantics cheaply)
    already = (rows == fresh[:, None]).any(axis=1)
    apply_row = do_mut & mut_rows & (~already)
    old_vals = rows[jnp.arange(phi), slot]
    new_rows = rows.at[jnp.arange(phi), slot].set(
        jnp.where(apply_row, fresh, old_vals)
    )

    # --- column mutation: swap one ON (non-target) for one OFF column -------
    tgt = jnp.zeros((M,), bool).at[target].set(True)
    def col_mut(k, cm):
        ka, kb = jax.random.split(k)
        off = _sample_members(ka, cm & (~tgt), 1)   # one member to drop
        on = _sample_members(kb, ~cm, 1)            # one non-member to add
        ok = (off.sum() == 1) & (on.sum() == 1)
        return jnp.where(ok, (cm & ~off) | on, cm)
    mutated_cols = jax.vmap(col_mut)(jax.random.split(k5, phi), cols)
    apply_col = (do_mut & (~mut_rows))[:, None]
    new_cols = jnp.where(apply_col, mutated_cols, cols)
    return new_rows, new_cols, apply_row, old_vals, fresh


def _mutate(key, rows, cols, *, N, M, n, m, xi, p_rc, target):
    new_rows, new_cols, _, _, _ = _mutate_core(
        key, rows, cols, N=N, M=M, n=n, m=m, xi=xi, p_rc=p_rc, target=target
    )
    return new_rows, new_cols


def _crossover_splits(key, half, n, m):
    """Independent row/column crossover split sizes.

    Draws ``s_r`` (how many rows child_ab takes from parent a) and ``s_c``
    (how many columns) from *separate* keys.  A single shared key here
    correlates the two draws — with identical ranges (``n == m - 1``) the
    row and column split points would be bit-identical every generation —
    so each geometry axis gets its own fold of ``key``."""
    ksr, ksc = jax.random.split(key)
    s_r = jax.random.randint(ksr, (half,), 1, jnp.maximum(n, 2))
    s_c = jax.random.randint(ksc, (half,), 1, jnp.maximum(m - 1, 2))
    return s_r, s_c


def _crossover(key, rows, cols, *, N, M, n, m, p_rc, target):
    """Pairwise split-and-swap crossover over the whole population."""
    phi = rows.shape[0]
    half = phi // 2
    kp, kt, ks, kra, krb, kca, kcb, kfa, kfb, kda, kdb = jax.random.split(key, 11)

    perm = jax.random.permutation(kp, phi)
    ra, rb = rows[perm[:half]], rows[perm[half:]]
    ca, cb = cols[perm[:half]], cols[perm[half:]]

    cross_rows = jax.random.uniform(kt, (half,)) < p_rc

    s_r, s_c = _crossover_splits(ks, half, n, m)

    # --- row crossover: child_ab = s rows of a + (n-s) rows of b ------------
    pa = jax.vmap(lambda k, r: jax.random.permutation(k, r))(
        jax.random.split(kra, half), ra
    )
    pb = jax.vmap(lambda k, r: jax.random.permutation(k, r))(
        jax.random.split(krb, half), rb
    )
    take_a = jnp.arange(n)[None, :] < s_r[:, None]
    child_ab_rows = jnp.where(take_a, pa, pb)   # s from a, rest from b
    child_ba_rows = jnp.where(take_a, pb, pa)
    child_ab_rows = jax.vmap(_dedup_rows, in_axes=(0, 0, None))(
        jax.random.split(kda, half), child_ab_rows, N
    )
    child_ba_rows = jax.vmap(_dedup_rows, in_axes=(0, 0, None))(
        jax.random.split(kdb, half), child_ba_rows, N
    )

    # --- column crossover: union of s members of a and (m-s) of b, refill ---
    tgt = jnp.zeros((M,), bool).at[target].set(True)
    def col_child(k, kf, cma, cmb, s):
        k1, k2 = jax.random.split(k)
        u = _sample_members(k1, cma & ~tgt, s) | _sample_members(
            k2, cmb & ~tgt, m - 1 - s
        )
        u = u | tgt
        return _refill_to(kf, u, m)
    child_ab_cols = jax.vmap(col_child)(
        jax.random.split(kca, half), jax.random.split(kfa, half), ca, cb, s_c
    )
    child_ba_cols = jax.vmap(col_child)(
        jax.random.split(kcb, half), jax.random.split(kfb, half), cb, ca, s_c
    )

    # row-cross keeps own columns; col-cross keeps own rows (paper §3.3)
    ab_rows = jnp.where(cross_rows[:, None], child_ab_rows, ra)
    ba_rows = jnp.where(cross_rows[:, None], child_ba_rows, rb)
    ab_cols = jnp.where(cross_rows[:, None], ca, child_ab_cols)
    ba_cols = jnp.where(cross_rows[:, None], cb, child_ba_cols)

    new_rows = jnp.concatenate([ab_rows, ba_rows], axis=0)
    new_cols = jnp.concatenate([ab_cols, ba_cols], axis=0)
    return new_rows, new_cols


def _select_idx(key, fitness, *, alpha):
    """Royalty tournament: keep top alpha*phi, sample the rest ∝ fitness."""
    phi = fitness.shape[0]
    n_elite = max(1, int(round(alpha * phi)))
    order = jnp.argsort(-fitness)
    elite = order[:n_elite]
    # fitness-proportional sampling on shifted fitness (fitness <= 0)
    w = fitness - fitness.min() + 1e-9
    drawn = jax.random.choice(key, phi, (phi - n_elite,), replace=True, p=w / w.sum())
    return jnp.concatenate([elite, drawn])


def _select(key, rows, cols, fitness, *, alpha):
    keep = _select_idx(key, fitness, alpha=alpha)
    return rows[keep], cols[keep]


# ---------------------------------------------------------------------------
# island migration
# ---------------------------------------------------------------------------


def _ring_migrate(rows, cols, counts, fit, *, k):
    """Replace each island's worst k candidates with its neighbour's best k.

    All arrays carry an (num_islands, phi, ...) leading pair; the ring is a
    roll over the island axis, so migration is one gather + one scatter.
    """
    I, phi = fit.shape

    def gather(x, idx):
        return jnp.take_along_axis(
            x, idx.reshape(idx.shape + (1,) * (x.ndim - 2)), axis=1
        )

    order = jnp.argsort(-fit, axis=1)
    best_i, worst_i = order[:, :k], order[:, phi - k:]
    ai = jnp.arange(I)[:, None]

    def swap(x):
        incoming = jnp.roll(gather(x, best_i), 1, axis=0)
        return x.at[ai, worst_i].set(incoming)

    return swap(rows), swap(cols), swap(counts), swap(fit)


# ---------------------------------------------------------------------------
# main entry point
# ---------------------------------------------------------------------------


def _gen_dst_core(key, codes, values, n, m, cfg: GenDSTConfig, B, target):
    """Trace-level GA body shared by the solo jit and the vmapped batch jit
    (``gen_dst_batch``): one definition, so a batched search runs the exact
    same per-search math as a solo one."""
    note_trace("gen_dst._gen_dst_core")   # body runs only while tracing
    N, M = codes.shape
    I, phi = cfg.num_islands, cfg.phi
    entropy = cfg.measure == "entropy"
    interpret = resolve_interpret(None)

    use_fused = entropy and cfg.backend == "pallas_fused"

    def pop_counts(rows):
        # full-recompute histograms: the fused backend shares the entropy
        # kernel's MXU one-hot-contraction path (DESIGN.md §16.3)
        hist_backend = "pallas" if cfg.backend in ("pallas", "pallas_fused") \
            else "jnp"
        return _population_counts(
            codes, rows, B, backend=hist_backend, interpret=interpret
        )

    if entropy:
        h_full = full_column_entropy(codes, B)
        f_ref = h_full.mean()
    else:
        measure_fn = MEASURES[cfg.measure]
        f_ref = measure_fn(values)

    def fitness_of(rows, cols, counts):
        if entropy:
            return _counts_fitness(counts, cols, f_ref)
        return jax.vmap(
            lambda r, c: _generic_fitness(values, measure_fn, f_ref, r, c)
        )(rows, cols)

    mutate1 = functools.partial(
        _mutate_core, N=N, M=M, n=n, m=m, xi=cfg.xi, p_rc=cfg.p_rc, target=target
    )
    cross1 = functools.partial(
        _crossover, N=N, M=M, n=n, m=m, p_rc=cfg.p_rc, target=target
    )

    k0, kloop = jax.random.split(key)
    rows, cols = jax.vmap(
        lambda kk: _init_population(kk, N, M, n, m, phi, target)
    )(jax.random.split(k0, I))                                  # (I, phi, ...)
    counts0 = pop_counts(rows) if entropy else jnp.zeros((I, phi, 1, 1), jnp.float32)
    fit0 = fitness_of(rows, cols, counts0)
    flat0 = fit0.reshape(-1)
    b0 = jnp.argmax(flat0)
    carry0 = (
        rows, cols, counts0,
        flat0[b0], rows.reshape(I * phi, n)[b0], cols.reshape(I * phi, M)[b0],
        kloop,
    )

    def generation(carry, gen_idx):
        rows, cols, counts, best_f, best_r, best_c, key = carry
        key, km, kx, ksel = jax.random.split(key, 4)

        rows1, cols1, applied, old_vals, fresh = jax.vmap(mutate1)(
            jax.random.split(km, I), rows, cols
        )
        xkeys = jax.random.split(kx, I)

        if use_fused:
            # §16 path: the cond only decides *which counts and delta* feed
            # the fused kernel; delta-update + fitness always run as one
            # launch.  Crossover generations rebuild histograms on the MXU
            # path and pass a zero delta, so both branches share one
            # fitness code path (and one jaxpr shape for the cond).
            no_delta = jnp.zeros_like(applied)

            def with_cross(_):
                rows2, cols2 = jax.vmap(cross1)(xkeys, rows1, cols1)
                return rows2, cols2, pop_counts(rows2), no_delta

            def without_cross(_):
                if cfg.incremental:
                    return rows1, cols1, counts, applied
                return rows1, cols1, pop_counts(rows1), no_delta

            if cfg.cross_every == 1:
                rows2, cols2, counts_b, app = with_cross(None)
            else:
                rows2, cols2, counts_b, app = jax.lax.cond(
                    gen_idx % cfg.cross_every == 0,
                    with_cross, without_cross, None,
                )
            counts2, fit = fused_delta_fitness(
                counts_b,
                jnp.take(codes, old_vals, axis=0),
                jnp.take(codes, fresh, axis=0),
                app, cols2, f_ref,
                backend="pallas_fused", interpret=interpret,
            )
        else:
            def with_cross(_):
                rows2, cols2 = jax.vmap(cross1)(xkeys, rows1, cols1)
                counts2 = pop_counts(rows2) if entropy else counts
                return rows2, cols2, counts2

            def without_cross(_):
                if not entropy:
                    return rows1, cols1, counts
                if cfg.incremental:
                    counts2 = jax.vmap(
                        lambda c, o, f_, a: _row_delta(codes, c, o, f_, a)
                    )(counts, old_vals, fresh, applied)
                else:
                    counts2 = pop_counts(rows1)
                return rows1, cols1, counts2

            if cfg.cross_every == 1:
                rows2, cols2, counts2 = with_cross(None)
            else:
                rows2, cols2, counts2 = jax.lax.cond(
                    gen_idx % cfg.cross_every == 0, with_cross, without_cross,
                    None,
                )
            fit = fitness_of(rows2, cols2, counts2)             # (I, phi)
        flat = fit.reshape(-1)
        g = jnp.argmax(flat)
        better = flat[g] > best_f
        best_f = jnp.where(better, flat[g], best_f)
        best_r = jnp.where(better, rows2.reshape(I * phi, n)[g], best_r)
        best_c = jnp.where(better, cols2.reshape(I * phi, M)[g], best_c)

        if I > 1:
            k_mig = max(1, int(round(cfg.migrate_frac * phi)))
            rows2, cols2, counts2, fit = jax.lax.cond(
                (gen_idx + 1) % cfg.migrate_every == 0,
                lambda op: _ring_migrate(*op, k=k_mig),
                lambda op: op,
                (rows2, cols2, counts2, fit),
            )

        keep = jax.vmap(lambda kk, f_: _select_idx(kk, f_, alpha=cfg.alpha))(
            jax.random.split(ksel, I), fit
        )                                                       # (I, phi)

        def take(x):
            return jnp.take_along_axis(
                x, keep.reshape(keep.shape + (1,) * (x.ndim - 2)), axis=1
            )

        carry_out = (take(rows2), take(cols2), take(counts2),
                     best_f, best_r, best_c, key)
        return carry_out, best_f

    carry, history = jax.lax.scan(generation, carry0, jnp.arange(cfg.psi))
    _, _, _, best_f, best_r, best_c, _ = carry
    return best_r, best_c, best_f, history, f_ref


_gen_dst_jit = functools.partial(
    jax.jit, static_argnames=("n", "m", "cfg", "B", "target")
)(_gen_dst_core)


@functools.partial(jax.jit, static_argnames=("n", "m", "cfg", "B", "target"))
def _gen_dst_batch_jit(keys, codes, values, n, m, cfg: GenDSTConfig, B, target):
    return jax.vmap(
        lambda k, cd, vl: _gen_dst_core(k, cd, vl, n, m, cfg, B, target)
    )(keys, codes, values)


def gen_dst(
    key: jax.Array,
    coded: CodedDataset,
    n: Optional[int] = None,
    m: Optional[int] = None,
    cfg: GenDSTConfig = GenDSTConfig(),
) -> DSTResult:
    """Run Gen-DST on a factorized dataset; returns the best DST found."""
    N, M = coded.codes.shape
    dn, dm = default_dst_size(N, M)
    n = dn if n is None else min(n, N)
    m = dm if m is None else min(m, M)
    _validate_cfg(cfg)
    best_r, best_c, best_f, history, f_ref = _gen_dst_jit(
        key, coded.codes, coded.values, n, m, cfg, coded.max_bins, coded.target_col
    )
    return DSTResult(best_r, best_c, best_f, history, f_ref)


def gen_dst_batch(
    keys,
    codeds,
    n: Optional[int] = None,
    m: Optional[int] = None,
    cfg: GenDSTConfig = GenDSTConfig(),
) -> list[DSTResult]:
    """Run Gen-DST on several same-shaped datasets in one vmapped dispatch.

    ``keys``/``codeds`` are parallel sequences; every dataset must share the
    same ``codes`` shape, ``max_bins`` and ``target_col`` (the static axes
    of the jitted GA).  The searches are independent — vmap only changes the
    dispatch granularity, exactly like the AutoML engine's cross-job rung
    merge — so each result matches a solo ``gen_dst`` run with the same key.
    The service scheduler batches concurrent cache-miss jobs through this
    (DESIGN.md §12.4)."""
    if len(keys) != len(codeds) or not codeds:
        raise ValueError("gen_dst_batch: keys and codeds must be equal-length"
                         " non-empty sequences")
    c0 = codeds[0]
    for c in codeds[1:]:
        if (c.codes.shape != c0.codes.shape or c.max_bins != c0.max_bins
                or c.target_col != c0.target_col):
            raise ValueError("gen_dst_batch: all datasets must share the "
                             "codes shape, max_bins, and target_col")
    N, M = c0.codes.shape
    dn, dm = default_dst_size(N, M)
    n = dn if n is None else min(n, N)
    m = dm if m is None else min(m, M)
    _validate_cfg(cfg)
    rb, cb, fb, hist, f_ref = _gen_dst_batch_jit(
        jnp.stack(list(keys)),
        jnp.stack([c.codes for c in codeds]),
        jnp.stack([c.values for c in codeds]),
        n, m, cfg, c0.max_bins, c0.target_col,
    )
    return [DSTResult(rb[i], cb[i], fb[i], hist[i], f_ref[i])
            for i in range(len(codeds))]


def random_dst(key, coded: CodedDataset, n: Optional[int] = None, m: Optional[int] = None):
    """A uniformly random DST (the paper's trivial baseline building block)."""
    N, M = coded.codes.shape
    dn, dm = default_dst_size(N, M)
    n = dn if n is None else min(n, N)
    m = dm if m is None else min(m, M)
    rows, cols = _init_population(key, N, M, n, m, 2, coded.target_col)
    return DSTResult(rows[0], cols[0], jnp.float32(jnp.nan), jnp.zeros((0,)), jnp.float32(jnp.nan))
