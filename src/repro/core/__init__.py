# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public surface: the plan-based pipeline API (DESIGN.md §12).
from .plan import Plan, execute, plan, plan_from_config
from .strategies import (
    SubsetResult, available_strategies, get_strategy, register_strategy,
    run_strategy,
)

__all__ = [
    "Plan", "plan", "execute", "plan_from_config",
    "SubsetResult", "register_strategy", "get_strategy",
    "available_strategies", "run_strategy",
]
