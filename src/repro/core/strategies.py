"""SubsetStrategy registry — "how the subset is found" as a pluggable axis.

The paper frames SubStrat as a *wrapper* strategy around any AutoML tool
(§1.1), and its own evaluation (§4.2, Table 3) treats subset selection as a
family of interchangeable methods: Gen-DST, Monte-Carlo search, bandits,
greedy selection, clustering, information gain.  Related work pushes the
same framing further (ASP's automatic proxy-data selection, arXiv
2310.11478; Layered TPOT's staged subset evaluation, arXiv 1801.06007).
This module makes that the API: every way of producing a
measure-preserving subset is a **SubsetStrategy** — a callable

    (key, coded: CodedDataset, n, m, **opts) -> DSTResult-like

registered under a name — and every strategy's output is normalized to one
uniform host-side ``SubsetResult``, which is what ``plan()``/``execute()``
(core/plan.py) and the service layer consume.  Because the payload is
uniform, *any* registered strategy can be cached by the DST cache and
served by the scheduler, not just Gen-DST.

Strategies that expose a ``batch_fn`` can additionally evaluate several
same-shaped searches in one vmapped dispatch (``gen_dst_batch``): the
scheduler uses this to fuse concurrent cache-miss jobs' searches the way
rung cohorts merge (DESIGN.md §12.4).

Third-party registration::

    from repro.core.strategies import register_strategy
    register_strategy("my_dst", my_fn)           # -> usable in any Plan

Unknown names raise ``KeyError`` listing every registered strategy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .gen_dst import DSTResult, GenDSTConfig, gen_dst, gen_dst_batch, random_dst
from .measures import (
    CodedDataset,
    column_entropy_from_counts,
    full_column_entropy,
    subset_counts,
)

__all__ = [
    "SubsetResult", "StrategySpec", "register_strategy", "get_strategy",
    "available_strategies", "run_strategy", "run_strategy_batch",
    "asp_proxy_dst", "STRATEGIES",
]


@dataclasses.dataclass(frozen=True)
class SubsetResult:
    """Uniform host-side output of every SubsetStrategy.

    This is the one payload the executor, the DST cache, and the scheduler
    handle — strategies may return richer device-side structures
    (``DSTResult``), but everything downstream of strategy execution sees
    exactly this."""
    row_idx: np.ndarray        # (n,) host int32 row indices
    col_mask: np.ndarray       # (M,) host bool column mask (target incl.)
    fitness: float             # -|F(d) - F(D)| (NaN for unscored strategies)
    strategy: str              # registry name (or "<callable>")
    time_s: float              # wall seconds spent producing the subset


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """One registered SubsetStrategy.

    ``fn(key, coded, n, m, **opts)`` returns a DSTResult-like with
    ``row_idx`` / ``col_mask`` / ``fitness`` fields.  ``batch_fn``, when
    set, evaluates many same-shaped searches at once:
    ``batch_fn(keys, codeds, n, m, **opts) -> [DSTResult, ...]`` — the
    scheduler merges concurrent cache-miss jobs through it.  ``cacheable``
    marks strategies whose output is a pure function of
    ``(dataset, n, m, opts)`` given the key — those are DST-cache eligible.
    """
    name: str
    fn: Callable
    batch_fn: Optional[Callable] = None
    cacheable: bool = True
    description: str = ""


STRATEGIES: Dict[str, StrategySpec] = {}


def register_strategy(
    name: str,
    fn: Callable,
    *,
    batch_fn: Optional[Callable] = None,
    cacheable: bool = True,
    description: str = "",
    overwrite: bool = False,
) -> StrategySpec:
    """Register a SubsetStrategy under ``name``; returns its spec."""
    if not overwrite and name in STRATEGIES:
        raise ValueError(f"strategy {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    spec = StrategySpec(name=name, fn=fn, batch_fn=batch_fn,
                        cacheable=cacheable, description=description)
    STRATEGIES[name] = spec
    return spec


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(STRATEGIES))


def get_strategy(name: str) -> StrategySpec:
    """Look up a registered strategy; unknown names list what exists."""
    try:
        return STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown subset strategy {name!r}; available strategies: "
            f"{', '.join(available_strategies())}") from None


def _to_subset_result(dst, strategy: str, time_s: float) -> SubsetResult:
    return SubsetResult(
        row_idx=np.asarray(jax.device_get(dst.row_idx)),
        col_mask=np.asarray(jax.device_get(dst.col_mask)),
        fitness=float(dst.fitness),
        strategy=strategy,
        time_s=time_s,
    )


def run_strategy(
    strategy: Union[str, Callable],
    key: jax.Array,
    coded: CodedDataset,
    n: Optional[int],
    m: Optional[int],
    opts: Sequence[Tuple[str, object]] = (),
) -> SubsetResult:
    """Execute one strategy and normalize its output to a ``SubsetResult``.

    ``strategy`` is a registry name or a bare callable (the old ``dst_fn``
    escape hatch); ``opts`` is a ``(key, value)`` item sequence (the
    hashable form ``Plan`` carries) forwarded as keyword arguments."""
    if callable(strategy):
        fn, name = strategy, getattr(strategy, "__name__", "<callable>")
        kwargs = dict(opts)
    else:
        spec = get_strategy(strategy)
        fn, name = spec.fn, spec.name
        kwargs = dict(opts)
    t0 = time.perf_counter()
    dst = fn(key, coded, n, m, **kwargs)
    return _to_subset_result(dst, name, time.perf_counter() - t0)


def run_strategy_batch(
    strategy: str,
    keys: Sequence[jax.Array],
    codeds: Sequence[CodedDataset],
    n: Optional[int],
    m: Optional[int],
    opts: Sequence[Tuple[str, object]] = (),
) -> List[SubsetResult]:
    """Execute one batchable strategy over several same-shaped datasets in
    a single vmapped dispatch; falls back to per-dataset execution when the
    strategy has no ``batch_fn``."""
    spec = get_strategy(strategy)
    t0 = time.perf_counter()
    if spec.batch_fn is None:
        return [run_strategy(strategy, k, c, n, m, opts)
                for k, c in zip(keys, codeds)]
    dsts = spec.batch_fn(keys, codeds, n, m, **dict(opts))
    share = (time.perf_counter() - t0) / max(len(dsts), 1)
    return [_to_subset_result(d, spec.name, share) for d in dsts]


# ---------------------------------------------------------------------------
# ASP-style proxy scorer (arXiv 2310.11478 flavor)
# ---------------------------------------------------------------------------


def _entropy_fitness_of(coded: CodedDataset, rows: jax.Array, cm: jax.Array):
    f_ref = full_column_entropy(coded.codes, coded.max_bins).mean()
    h = column_entropy_from_counts(
        subset_counts(coded.codes, rows, coded.max_bins))
    cmf = cm.astype(jnp.float32)
    f_d = jnp.sum(h * cmf) / jnp.maximum(cmf.sum(), 1.0)
    return -jnp.abs(f_d - f_ref), f_ref


def asp_proxy_dst(key, coded: CodedDataset, n=None, m=None, *,
                  hard_frac: float = 0.5):
    """ASP-style automatic proxy-data selection (cf. arXiv 2310.11478).

    Instead of searching for a measure-preserving subset, score each row by
    a cheap *proxy* of its training value and assemble the subset directly:

    - **Columns**: the ``m-1`` highest information-gain features (the proxy
      model's relevance ranking) + the target.
    - **Rows**: per-class stratified selection by a nearest-class-centroid
      margin (distance to own centroid minus distance to the best other
      centroid — the proxy model's difficulty score).  Each class gets a
      slot count proportional to its frequency (>= 1, so rare classes
      survive), filled with an even quantile sweep over that class's
      difficulty ranking: a ``hard_frac``-controlled mix of easy
      (prototypical) and hard (boundary) examples.

    One pass over the data, no search loop; the returned fitness is the
    same entropy score every other strategy reports, so ASP subsets are
    comparable to searched ones."""
    from .baselines import _ig_cols, _resolve_nm  # no import cycle

    n, m = _resolve_nm(coded, n, m)
    tgt = coded.target_col

    # columns: IG ranking (proxy feature relevance) — the shared rule the
    # IG baselines use (top m-1 by gain + the target)
    col_mask = np.asarray(jax.device_get(_ig_cols(coded, m)))

    # rows: class-stratified margin quantiles (proxy difficulty)
    vals = np.asarray(jax.device_get(coded.values))
    y = np.asarray(jax.device_get(coded.codes))[:, tgt]
    feats = np.delete(np.arange(vals.shape[1]), tgt)
    Z = vals[:, feats]
    Z = (Z - Z.mean(0)) / (Z.std(0) + 1e-9)
    classes, counts = np.unique(y, return_counts=True)
    cents = np.stack([Z[y == c].mean(0) for c in classes])       # (C, d)
    d2 = ((Z[:, None, :] - cents[None]) ** 2).sum(-1)            # (N, C)
    own = d2[np.arange(len(y)), np.searchsorted(classes, y)]
    other = np.where(
        np.arange(len(classes))[None] == np.searchsorted(classes, y)[:, None],
        np.inf, d2).min(1)
    margin = own - other          # low = prototypical, high = boundary

    # proportional slots, every class >= 1; trim largest classes on overflow
    slots = np.maximum(1, np.round(n * counts / counts.sum()).astype(int))
    while slots.sum() > n:
        slots[np.argmax(slots)] -= 1
    while slots.sum() < n:
        slots[np.argmax(counts - slots)] += 1

    seed = int(np.asarray(jax.device_get(jax.random.randint(
        jax.random.fold_in(key, 0xA59), (), 0, np.iinfo(np.int32).max))))
    rng = np.random.default_rng(seed)
    rows = []
    for cls, k in zip(classes, slots):
        members = np.flatnonzero(y == cls)
        k = min(int(k), len(members))
        order = members[np.argsort(margin[members])]
        # quantile sweep over the easy..hard ranking; hard_frac biases how
        # deep into the boundary region the sweep reaches
        span = max(1, int(round(len(order) * (0.5 + 0.5 * hard_frac))))
        pick = np.unique(np.linspace(0, span - 1, k).round().astype(int))
        chosen = order[pick]
        if len(chosen) < k:   # rounding collisions: fill with random members
            pool = np.setdiff1d(order, chosen)
            chosen = np.concatenate(
                [chosen, rng.choice(pool, k - len(chosen), replace=False)])
        rows.append(chosen)
    row_idx = np.sort(np.concatenate(rows))[:n].astype(np.int32)

    rows_j = jnp.asarray(row_idx)
    cm_j = jnp.asarray(col_mask)
    fitness, f_ref = _entropy_fitness_of(coded, rows_j, cm_j)
    return DSTResult(rows_j, cm_j, fitness, jnp.zeros((0,)), f_ref)


# ---------------------------------------------------------------------------
# built-in registrations
# ---------------------------------------------------------------------------


def _register_builtins() -> None:
    from . import baselines as B

    def _gen(key, coded, n, m, *, cfg: GenDSTConfig = GenDSTConfig(), **kw):
        if kw:
            cfg = cfg._replace(**kw)
        return gen_dst(key, coded, n, m, cfg)

    def _gen_batch(keys, codeds, n, m, *, cfg: GenDSTConfig = GenDSTConfig(),
                   **kw):
        if kw:
            cfg = cfg._replace(**kw)
        return gen_dst_batch(keys, codeds, n, m, cfg)

    def _gen_islands(key, coded, n, m, *, cfg: GenDSTConfig = GenDSTConfig(),
                     num_islands: int = 4, **kw):
        cfg = cfg._replace(num_islands=num_islands, **kw)
        return gen_dst(key, coded, n, m, cfg)

    def _gen_islands_batch(keys, codeds, n, m, *,
                           cfg: GenDSTConfig = GenDSTConfig(),
                           num_islands: int = 4, **kw):
        cfg = cfg._replace(num_islands=num_islands, **kw)
        return gen_dst_batch(keys, codeds, n, m, cfg)

    register_strategy("gen_dst", _gen, batch_fn=_gen_batch,
                      description="the paper's genetic DST search (§3.3)")
    register_strategy("gen_dst_islands", _gen_islands,
                      batch_fn=_gen_islands_batch,
                      description="island-parallel Gen-DST (DESIGN.md §5.5)")
    register_strategy("random", random_dst, cacheable=False,
                      description="uniform random subset (trivial baseline)")
    register_strategy("mc", B.mc_dst,
                      description="Monte-Carlo search (paper §4.2 cat. A)")
    register_strategy("mab", B.mab_dst,
                      description="eps-greedy multi-arm bandit (cat. B)")
    register_strategy("greedy_seq", B.greedy_seq_dst,
                      description="greedy rows-then-columns (cat. C)")
    register_strategy("greedy_mult", B.greedy_mult_dst,
                      description="greedy row+column co-selection (cat. C)")
    register_strategy("km", B.km_dst,
                      description="k-means representatives (cat. D)")
    register_strategy("ig_rand", B.ig_rand_dst,
                      description="IG columns + random rows (cat. E)")
    register_strategy("ig_km", B.ig_km_dst,
                      description="IG columns + k-means rows (cat. E)")
    register_strategy("asp_proxy", asp_proxy_dst,
                      description="ASP-style proxy-data scorer "
                                  "(arXiv 2310.11478 flavor)")


_register_builtins()
