"""SubStrat — the paper's 3-step subset-based AutoML strategy (§1.1, Fig. 1).

  1. Find a small measure-preserving data subset d (Gen-DST, or any of the
     baseline DST generators — pluggable via ``dst_fn``).
  2. Run the AutoML tool on d:  A(d, y) -> M'.
  3. Fine-tune: run a *restricted, much shorter* AutoML pass on the full D,
     only considering pipelines with M''s model family:  -> M_sub.

``fine_tune=False`` gives the paper's SubStrat-NF ablation (category F).

Since the plan-based API redesign (DESIGN.md §12), ``substrat()`` is a thin
client of ``core/plan.py``: it converts its ``SubStratConfig`` (and the
deprecated ``dst_fn=`` escape hatch) into a declarative ``Plan`` via
``plan_from_config`` and hands it to ``execute()`` — one driver shared with
the service scheduler.  The phase functions — ``dst_feature_columns``,
``build_subset``, ``nf_test_eval`` — remain the shared units of work both
paths run; ``phase_dst`` survives as a compatibility wrapper over the
SubsetStrategy registry (``core/strategies.py``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax
import numpy as np

from ..automl.engine import AutoMLConfig, AutoMLResult
from .gen_dst import GenDSTConfig
from .measures import CodedDataset

__all__ = [
    "SubStratResult", "substrat", "SubStratConfig",
    "phase_dst", "dst_feature_columns", "build_subset", "nf_test_eval",
]


@dataclasses.dataclass(frozen=True)
class SubStratConfig:
    """Configuration of the full 3-step strategy (paper §1.1, DESIGN.md §5).

    Every field states its paper section or DESIGN.md anchor:

    - ``gen`` — Gen-DST GA budget and search-loop levers (paper §3.3,
      DESIGN.md §5.3/§5.5).
    - ``n`` / ``m`` — DST shape; ``None`` means the paper defaults
      ``sqrt(N)`` rows and ``0.25·M`` columns (paper §4.2).
    - ``fine_tune`` — step 3 on/off; ``False`` is the paper's SubStrat-NF
      ablation (paper §4.4 category F).
    - ``sub_automl`` — step-2 engine budget ``A(d, y) -> M'`` on the subset
      (paper §3.4, DESIGN.md §10.2).
    - ``ft_automl`` — the "restricted, much shorter" step-3 pass on the full
      data, constrained to M''s family (paper §3.4, DESIGN.md §10.2).
    - ``num_islands`` / ``dst_backend`` — Gen-DST overrides (DESIGN.md §5.5,
      §16); when set they win over the corresponding ``gen`` fields, so
      callers can turn on islands or switch the accelerator backend
      (``"jnp"``/``"pallas"``/``"pallas_fused"``) without rebuilding the
      whole GenDSTConfig.  The override rides the GenDSTConfig into the
      Plan's ``strategy_opts`` — and therefore into the service DST-cache
      key — unchanged.
    - ``automl_backend`` — AutoML-engine execution override (DESIGN.md §10.3):
      ``"batched"`` (vmap cohort) or ``"loop"`` (sequential reference),
      applied to *both* the sub-AutoML and fine-tune passes when set.
    """
    gen: GenDSTConfig = GenDSTConfig()
    n: Optional[int] = None           # DST rows (default sqrt(N), paper §4.2)
    m: Optional[int] = None           # DST cols (default 0.25*M, paper §4.2)
    fine_tune: bool = True            # False => SubStrat-NF (paper §4.4)
    sub_automl: AutoMLConfig = AutoMLConfig()
    # "restricted, much shorter" pass on the full data (paper §3.4):
    ft_automl: AutoMLConfig = AutoMLConfig(n_trials=6, rungs=(60,))
    # Gen-DST search-loop overrides (DESIGN.md §5.5)
    num_islands: Optional[int] = None
    dst_backend: Optional[str] = None
    # AutoML engine backend override (DESIGN.md §10.3)
    automl_backend: Optional[str] = None

    def resolved_gen(self) -> GenDSTConfig:
        gen = self.gen
        if self.num_islands is not None:
            gen = gen._replace(num_islands=self.num_islands)
        if self.dst_backend is not None:
            gen = gen._replace(backend=self.dst_backend)
        return gen

    def resolved_sub_automl(self) -> AutoMLConfig:
        if self.automl_backend is not None:
            return dataclasses.replace(self.sub_automl, backend=self.automl_backend)
        return self.sub_automl

    def resolved_ft_automl(self) -> AutoMLConfig:
        if self.automl_backend is not None:
            return dataclasses.replace(self.ft_automl, backend=self.automl_backend)
        return self.ft_automl


@dataclasses.dataclass
class SubStratResult:
    final: AutoMLResult               # M_sub (or M' if fine_tune=False)
    intermediate: AutoMLResult        # M'
    row_idx: np.ndarray
    col_idx: np.ndarray               # selected feature columns (no target)
    dst_fitness: float
    times: dict                       # per-phase seconds
    total_time_s: float
    strategy: str = "gen_dst"         # SubsetStrategy that found the subset


# ---------------------------------------------------------------------------
# phase functions (the scheduler's units of work; substrat() chains them)
# ---------------------------------------------------------------------------


def phase_dst(
    key: jax.Array,
    coded: CodedDataset,
    config: SubStratConfig,
    dst_fn: Optional[Callable] = None,
):
    """Step 1: find the measure-preserving DST.

    Compatibility wrapper over the SubsetStrategy registry: the config (and
    optional ``dst_fn``) is converted to a ``Plan`` and the plan's strategy
    runs.  Returns ``(row_idx, col_mask, fitness)`` as host numpy/float —
    the exact payload the service DST cache stores."""
    from .plan import plan_from_config
    from .strategies import run_strategy
    p = plan_from_config(config, dst_fn)
    sub = run_strategy(p.strategy, key, coded, p.n, p.m, p.strategy_opts)
    return sub.row_idx, sub.col_mask, sub.fitness


def dst_feature_columns(col_mask: np.ndarray, target_col: int) -> np.ndarray:
    """Feature columns of the DST (the target column participates in the
    measure but is the label, not a feature)."""
    col_idx = np.flatnonzero(col_mask)
    col_idx = col_idx[col_idx != target_col]
    if len(col_idx) == 0:
        # degenerate DST (some baselines can select only the target on
        # tiny m) — fall back to the first feature column
        col_idx = np.array([0 if target_col != 0 else 1])
    return col_idx


def build_subset(
    X: np.ndarray,
    y: np.ndarray,
    row_idx: np.ndarray,
    col_idx: np.ndarray,
    key: Optional[jax.Array] = None,
):
    """Materialize the DST rows/columns as the step-2 training set.

    If the row draw misses entire label classes (skewed labels), patch the
    subset by drawing explicitly from rows of each missing class — a fixed
    random draw can miss a rare minority class entirely — with the draw
    seeded from the run ``key`` so repeat runs are deterministic per key.
    The per-class draw is capped at the subset size divided by the number
    of missing classes (>= 1 each), so the degenerate case — a tiny subset
    missing nearly *every* class (small ``n``, many classes) — patches with
    one representative per class instead of over-drawing a patch many times
    larger than the subset itself."""
    X, y = np.asarray(X), np.asarray(y)
    X_sub = X[row_idx][:, col_idx]
    y_sub = y[row_idx]
    missing = np.setdiff1d(np.unique(y), np.unique(y_sub))
    if len(missing):
        key = jax.random.key(0) if key is None else key
        seed = int(np.asarray(jax.random.randint(
            jax.random.fold_in(key, 0x5AB5), (), 0, np.iinfo(np.int32).max)))
        rng = np.random.default_rng(seed)
        per_class = max(1, len(row_idx) // len(missing))
        extra = np.concatenate([
            rng.choice(np.flatnonzero(y == cls),
                       size=min(32, per_class, int((y == cls).sum())),
                       replace=False)
            for cls in missing
        ])
        X_sub = np.concatenate([X_sub, X[extra][:, col_idx]])
        y_sub = np.concatenate([y_sub, y[extra]])
    return X_sub, y_sub


def nf_test_eval(
    intermediate: AutoMLResult,
    y_sub: np.ndarray,
    col_idx: np.ndarray,
    X_test: np.ndarray,
    y_test: np.ndarray,
) -> AutoMLResult:
    """SubStrat-NF test evaluation: score M' on the full-width test data
    restricted to the DST's feature columns (no fine-tune pass)."""
    from ..automl.engine import apply_pipeline
    from ..automl.models import accuracy
    import jax.numpy as jnp
    Xt = apply_pipeline(
        intermediate.spec, intermediate.pre_stats, intermediate.feat_idx,
        np.asarray(X_test, np.float32)[:, col_idx],
    )
    classes = np.unique(y_sub)
    yt = jnp.asarray(np.searchsorted(classes, np.asarray(y_test)))
    return dataclasses.replace(
        intermediate,
        test_acc=accuracy(intermediate.params, Xt, yt, intermediate.spec.family),
    )


def substrat(
    X: np.ndarray,
    y: np.ndarray,
    *,
    key: Optional[jax.Array] = None,
    config: SubStratConfig = SubStratConfig(),
    dst_fn: Optional[Callable] = None,
    coded: Optional[CodedDataset] = None,
    X_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
) -> SubStratResult:
    """One-shot single-tenant SubStrat run — a thin client of the plan API.

    The config blob (and the deprecated ``dst_fn``) is converted to a
    declarative ``Plan`` and executed by the one shared driver
    (``core/plan.execute``); results are identical to building the plan
    yourself."""
    from .plan import execute, plan_from_config
    if dst_fn is not None:
        warnings.warn(
            "substrat(dst_fn=...) is deprecated; pass the generator as a "
            "Plan strategy instead: execute(plan(my_fn, ...), X, y) or "
            "register it via repro.core.strategies.register_strategy",
            DeprecationWarning, stacklevel=2)
    return execute(plan_from_config(config, dst_fn), X, y, key=key,
                   coded=coded, X_test=X_test, y_test=y_test)
