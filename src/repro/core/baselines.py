"""The paper's 10 baseline DST generators (SubStrat §4.2, Table 3).

Categories:
  A. Monte-Carlo search  (MC-100 / MC-100K / MC-24H → ``mc_dst`` w/ budget)
  B. Multi-Arm Bandit    (``mab_dst`` — eps-greedy over row-arms + col-arms)
  C. Greedy selection    (``greedy_seq_dst``, ``greedy_mult_dst``)
  D. K-Means clustering  (``km_dst``)
  E. Information gain    (``ig_rand_dst``, ``ig_km_dst``)
  F. SubStrat-NF         (wrapper-level: substrat(..., fine_tune=False))

All baselines return ``(row_idx (n,), col_mask (M,))`` like Gen-DST, operate
on the same factorized ``CodedDataset`` and the same entropy loss, and run
jitted on device.  Greedy baselines take a per-step candidate pool (the paper
notes the exact greedy variants exceeded 24 h; the pool bound keeps them
runnable — set ``pool >= N`` for exact behaviour on small data).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .measures import (
    CodedDataset,
    column_counts,
    column_entropy_from_counts,
    full_column_entropy,
    subset_counts,
)
from .gen_dst import (
    DSTResult,
    _init_population,
    _entropy_fitness,
    _rank_desc,
    default_dst_size,
)

__all__ = [
    "mc_dst",
    "mab_dst",
    "greedy_seq_dst",
    "greedy_mult_dst",
    "km_dst",
    "ig_rand_dst",
    "ig_km_dst",
    "information_gain",
    "kmeans",
]


def _resolve_nm(coded: CodedDataset, n, m):
    N, M = coded.codes.shape
    dn, dm = default_dst_size(N, M)
    return (dn if n is None else min(n, N)), (dm if m is None else min(m, M))


# ---------------------------------------------------------------------------
# A. Monte-Carlo search
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n", "m", "budget", "batch", "B", "target"))
def _mc_jit(key, codes, n, m, budget, batch, B, target):
    N, M = codes.shape
    f_ref = full_column_entropy(codes, B).mean()
    n_batches = max(1, budget // batch)

    def body(carry, key_b):
        best_f, best_r, best_c = carry
        rows, cols = _init_population(key_b, N, M, n, m, batch, target)
        fit = _entropy_fitness(codes, B, f_ref, rows, cols)
        i = jnp.argmax(fit)
        better = fit[i] > best_f
        return (
            jnp.where(better, fit[i], best_f),
            jnp.where(better, rows[i], best_r),
            jnp.where(better, cols[i], best_c),
        ), fit[i]

    r0, c0 = _init_population(key, N, M, n, m, 2, target)
    carry0 = (jnp.float32(-jnp.inf), r0[0], c0[0])
    (best_f, best_r, best_c), hist = jax.lax.scan(
        body, carry0, jax.random.split(key, n_batches)
    )
    return best_r, best_c, best_f, hist, f_ref


def mc_dst(key, coded: CodedDataset, n=None, m=None, *, budget: int = 100, batch: int = 50):
    """Monte-Carlo search over random DSTs with a candidate budget."""
    n, m = _resolve_nm(coded, n, m)
    batch = min(batch, budget)
    r, c, f, hist, f_ref = _mc_jit(
        key, coded.codes, n, m, budget, batch, coded.max_bins, coded.target_col
    )
    return DSTResult(r, c, f, hist, f_ref)


# ---------------------------------------------------------------------------
# B. Multi-Arm Bandit (eps-greedy over row-arms and column-arms)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("n", "m", "rounds", "B", "target")
)
def _mab_jit(key, codes, n, m, rounds, B, target, eps):
    N, M = codes.shape
    f_ref = full_column_entropy(codes, B).mean()
    tgt = jnp.zeros((M,), bool).at[target].set(True)

    def pick(key, values, k, forbid_mask=None):
        """eps-greedy: noisy-argmax over value estimates; eps => pure noise."""
        kn, ke = jax.random.split(key)
        noise = jax.random.uniform(kn, values.shape) * 1e-3
        explore = jax.random.uniform(ke, ()) < eps
        scores = jnp.where(explore, jax.random.uniform(kn, values.shape), values + noise)
        if forbid_mask is not None:
            scores = scores - jnp.where(forbid_mask, jnp.inf, 0.0)
        return jnp.argsort(-scores)[:k]

    def body(carry, key_t):
        rv, cv, rn, cn, best_f, best_r, best_c = carry
        kr, kc = jax.random.split(key_t)
        r = pick(kr, rv, n).astype(jnp.int32)
        c_sel = pick(kc, cv, m - 1, forbid_mask=tgt).astype(jnp.int32)
        cm = tgt.at[c_sel].set(True)
        h = column_entropy_from_counts(subset_counts(codes, r, B))
        cmf = cm.astype(jnp.float32)
        f_d = jnp.sum(h * cmf) / jnp.maximum(cmf.sum(), 1.0)
        reward = -jnp.abs(f_d - f_ref)
        # incremental-mean update of the chosen arms
        rn = rn.at[r].add(1.0)
        cn2 = cn.at[c_sel].add(1.0)
        rv = rv.at[r].add((reward - rv[r]) / rn[r])
        cv = cv.at[c_sel].add((reward - cv[c_sel]) / cn2[c_sel])
        better = reward > best_f
        best_f = jnp.where(better, reward, best_f)
        best_r = jnp.where(better, r, best_r)
        best_c = jnp.where(better, cm, best_c)
        return (rv, cv, rn, cn2, best_f, best_r, best_c), reward

    r0, c0 = _init_population(key, N, M, n, m, 2, target)
    carry0 = (
        jnp.zeros((N,)), jnp.zeros((M,)), jnp.zeros((N,)), jnp.zeros((M,)),
        jnp.float32(-jnp.inf), r0[0], c0[0],
    )
    carry, hist = jax.lax.scan(body, carry0, jax.random.split(key, rounds))
    _, _, _, _, best_f, best_r, best_c = carry
    return best_r, best_c, best_f, hist, f_ref


def mab_dst(key, coded: CodedDataset, n=None, m=None, *, rounds: int = 200, eps: float = 0.15):
    n, m = _resolve_nm(coded, n, m)
    r, c, f, hist, f_ref = _mab_jit(
        key, coded.codes, n, m, rounds, coded.max_bins, coded.target_col, eps
    )
    return DSTResult(r, c, f, hist, f_ref)


# ---------------------------------------------------------------------------
# C. Greedy selection
# ---------------------------------------------------------------------------


def _greedy_cols(h: jax.Array, f_ref, m: int, target: int):
    """Greedy column selection given per-column entropies h (M,).

    Iteratively adds the column whose inclusion brings mean(H_sel) closest
    to f_ref.  Fixed-shape scan over m-1 steps."""
    M = h.shape[0]
    cm0 = jnp.zeros((M,), bool).at[target].set(True)

    def step(cm, _):
        cnt = cm.sum()
        cur = jnp.sum(h * cm) / jnp.maximum(cnt, 1)
        # candidate means if each column were added
        cand = (cur * cnt + h) / (cnt + 1)
        loss = jnp.abs(cand - f_ref) + jnp.where(cm, jnp.inf, 0.0)
        j = jnp.argmin(loss)
        return cm.at[j].set(True), None

    cm, _ = jax.lax.scan(step, cm0, None, length=m - 1)
    return cm


@functools.partial(jax.jit, static_argnames=("n", "m", "pool", "B", "target"))
def _greedy_seq_jit(key, codes, n, m, pool, B, target):
    N, M = codes.shape
    h_full = full_column_entropy(codes, B)
    f_ref = h_full.mean()

    # --- phase 1: greedy rows (all columns active), incremental histograms --
    def step(carry, key_t):
        counts, rows, t = carry
        cand = jax.random.randint(key_t, (pool,), 0, N, dtype=jnp.int32)
        cand_rows = jnp.take(codes, cand, axis=0)              # (pool, M)
        onehot = jax.nn.one_hot(cand_rows, B, dtype=jnp.float32)  # (pool, M, B)
        new_counts = counts[None] + onehot                     # (pool, M, B)
        h = column_entropy_from_counts(new_counts)             # (pool, M)
        loss = jnp.abs(h.mean(axis=-1) - f_ref)                # (pool,)
        i = jnp.argmin(loss)
        counts = new_counts[i]
        rows = rows.at[t].set(cand[i])
        return (counts, rows, t + 1), loss[i]

    carry0 = (jnp.zeros((M, B), jnp.float32), jnp.zeros((n,), jnp.int32), 0)
    (counts, rows, _), hist = jax.lax.scan(
        step, carry0, jax.random.split(key, n)
    )

    # --- phase 2: greedy columns w.r.t. the selected rows --------------------
    h_sub = column_entropy_from_counts(counts)
    cm = _greedy_cols(h_sub, f_ref, m, target)
    cmf = cm.astype(jnp.float32)
    f_d = jnp.sum(h_sub * cmf) / jnp.maximum(cmf.sum(), 1.0)
    return rows, cm, -jnp.abs(f_d - f_ref), hist, f_ref


def greedy_seq_dst(key, coded: CodedDataset, n=None, m=None, *, pool: int = 64):
    n, m = _resolve_nm(coded, n, m)
    r, c, f, hist, f_ref = _greedy_seq_jit(
        key, coded.codes, n, m, pool, coded.max_bins, coded.target_col
    )
    return DSTResult(r, c, f, hist, f_ref)


@functools.partial(jax.jit, static_argnames=("n", "m", "pool", "B", "target"))
def _greedy_mult_jit(key, codes, n, m, pool, B, target):
    """Greedy row+column co-selection: each step adds the best row, then the
    best column (until m columns), measuring loss on the growing subset."""
    N, M = codes.shape
    h_full = full_column_entropy(codes, B)
    f_ref = h_full.mean()
    tgt = jnp.zeros((M,), bool).at[target].set(True)

    def step(carry, inp):
        key_t, t = inp
        counts, rows, cm = carry
        cand = jax.random.randint(key_t, (pool,), 0, N, dtype=jnp.int32)
        cand_rows = jnp.take(codes, cand, axis=0)
        onehot = jax.nn.one_hot(cand_rows, B, dtype=jnp.float32)
        new_counts = counts[None] + onehot
        h = column_entropy_from_counts(new_counts)             # (pool, M)
        cmf = cm.astype(jnp.float32)
        f_d = jnp.sum(h * cmf[None], axis=-1) / jnp.maximum(cmf.sum(), 1.0)
        loss = jnp.abs(f_d - f_ref)
        i = jnp.argmin(loss)
        counts = new_counts[i]
        rows = rows.at[t].set(cand[i])
        # column step: add one column while fewer than m selected
        h_i = h[i]
        cnt = cm.sum()
        cur = jnp.sum(h_i * cmf) / jnp.maximum(cnt, 1)
        cand_mean = (cur * cnt + h_i) / (cnt + 1)
        closs = jnp.abs(cand_mean - f_ref) + jnp.where(cm, jnp.inf, 0.0)
        j = jnp.argmin(closs)
        cm = jnp.where(cnt < m, cm.at[j].set(True), cm)
        return (counts, rows, cm), loss[i]

    carry0 = (jnp.zeros((M, B), jnp.float32), jnp.zeros((n,), jnp.int32), tgt)
    (counts, rows, cm), hist = jax.lax.scan(
        step, carry0, (jax.random.split(key, n), jnp.arange(n))
    )
    h_sub = column_entropy_from_counts(counts)
    cmf = cm.astype(jnp.float32)
    f_d = jnp.sum(h_sub * cmf) / jnp.maximum(cmf.sum(), 1.0)
    return rows, cm, -jnp.abs(f_d - f_ref), hist, f_ref


def greedy_mult_dst(key, coded: CodedDataset, n=None, m=None, *, pool: int = 64):
    n, m = _resolve_nm(coded, n, m)
    r, c, f, hist, f_ref = _greedy_mult_jit(
        key, coded.codes, n, m, pool, coded.max_bins, coded.target_col
    )
    return DSTResult(r, c, f, hist, f_ref)


# ---------------------------------------------------------------------------
# D. K-Means clustering
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key, points: jax.Array, k: int, iters: int = 10):
    """Lloyd's k-means; returns (centroids (k,d), nearest-point index (k,))."""
    P, d = points.shape
    mu = points.std(axis=0) + 1e-9
    z = (points - points.mean(axis=0)) / mu
    init_idx = jax.random.choice(key, P, (k,), replace=False)
    cent = z[init_idx]

    def step(cent, _):
        d2 = ((z[:, None, :] - cent[None, :, :]) ** 2).sum(-1)   # (P, k)
        assign = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)    # (P, k)
        sums = onehot.T @ z                                       # (k, d)
        cnts = onehot.sum(0)[:, None]
        new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d2 = ((z[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
    nearest = jnp.argmin(d2, axis=0)                              # (k,)
    return cent, nearest.astype(jnp.int32)


def _km_rows(key, coded: CodedDataset, n: int, max_points: int = 16384):
    """n representative rows = nearest rows to n k-means centroids."""
    N = coded.values.shape[0]
    if N > max_points:
        sel = jax.random.choice(key, N, (max_points,), replace=False)
        pts = jnp.take(coded.values, sel, axis=0)
        _, nearest = kmeans(key, pts, n)
        return jnp.take(sel, nearest).astype(jnp.int32)
    _, nearest = kmeans(key, coded.values, n)
    return nearest


def _km_cols(key, coded: CodedDataset, m: int, max_dims: int = 2048):
    """m representative columns = nearest column-vectors to m centroids."""
    N, M = coded.values.shape
    tgt = coded.target_col
    if N > max_dims:
        sel = jax.random.choice(key, N, (max_dims,), replace=False)
        colpts = jnp.take(coded.values, sel, axis=0).T            # (M, max_dims)
    else:
        colpts = coded.values.T
    k = min(m - 1, M - 1)
    _, nearest = kmeans(key, colpts, k)
    cm = jnp.zeros((M,), bool).at[tgt].set(True).at[nearest].set(True)
    return cm


def km_dst(key, coded: CodedDataset, n=None, m=None):
    n, m = _resolve_nm(coded, n, m)
    kr, kc = jax.random.split(key)
    rows = _km_rows(kr, coded, n)
    cm = _km_cols(kc, coded, m)
    f_ref = full_column_entropy(coded.codes, coded.max_bins).mean()
    h = column_entropy_from_counts(subset_counts(coded.codes, rows, coded.max_bins))
    cmf = cm.astype(jnp.float32)
    f_d = jnp.sum(h * cmf) / jnp.maximum(cmf.sum(), 1.0)
    return DSTResult(rows, cm, -jnp.abs(f_d - f_ref), jnp.zeros((0,)), f_ref)


# ---------------------------------------------------------------------------
# E. Information gain
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("B", "target"))
def information_gain(codes: jax.Array, B: int, target: int) -> jax.Array:
    """IG(col j; y) = H(y) - H(y | x_j), from joint code histograms."""
    N, M = codes.shape
    y = codes[:, target]
    # joint counts per column: (M, B, B) would be large; loop via vmap on cols
    def per_col(cj):
        flat = cj * B + y
        joint = jnp.zeros((B * B,), jnp.float32).at[flat].add(1.0).reshape(B, B)
        pj = joint.sum(axis=1)                       # count of x=v
        cond = joint / jnp.maximum(pj[:, None], 1e-12)
        h_cond = -jnp.sum(
            jnp.where(cond > 0, cond * jnp.log2(jnp.maximum(cond, 1e-30)), 0.0), axis=1
        )                                            # (B,)
        return jnp.sum((pj / N) * h_cond)
    h_y_given_x = jax.vmap(per_col, in_axes=1)(codes)   # (M,)
    py = jnp.zeros((B,), jnp.float32).at[y].add(1.0) / N
    h_y = -jnp.sum(jnp.where(py > 0, py * jnp.log2(jnp.maximum(py, 1e-30)), 0.0))
    ig = h_y - h_y_given_x
    return ig.at[target].set(-jnp.inf)  # target never selects itself


def _ig_cols(coded: CodedDataset, m: int) -> jax.Array:
    ig = information_gain(coded.codes, coded.max_bins, coded.target_col)
    top = jnp.argsort(-ig)[: m - 1]
    return jnp.zeros((coded.num_cols,), bool).at[coded.target_col].set(True).at[top].set(True)


def ig_rand_dst(key, coded: CodedDataset, n=None, m=None):
    n, m = _resolve_nm(coded, n, m)
    cm = _ig_cols(coded, m)
    rows = jax.random.choice(key, coded.num_rows, (n,), replace=False).astype(jnp.int32)
    f_ref = full_column_entropy(coded.codes, coded.max_bins).mean()
    h = column_entropy_from_counts(subset_counts(coded.codes, rows, coded.max_bins))
    cmf = cm.astype(jnp.float32)
    f_d = jnp.sum(h * cmf) / jnp.maximum(cmf.sum(), 1.0)
    return DSTResult(rows, cm, -jnp.abs(f_d - f_ref), jnp.zeros((0,)), f_ref)


def ig_km_dst(key, coded: CodedDataset, n=None, m=None):
    n, m = _resolve_nm(coded, n, m)
    cm = _ig_cols(coded, m)
    rows = _km_rows(key, coded, n)
    f_ref = full_column_entropy(coded.codes, coded.max_bins).mean()
    h = column_entropy_from_counts(subset_counts(coded.codes, rows, coded.max_bins))
    cmf = cm.astype(jnp.float32)
    f_d = jnp.sum(h * cmf) / jnp.maximum(cmf.sum(), 1.0)
    return DSTResult(rows, cm, -jnp.abs(f_d - f_ref), jnp.zeros((0,)), f_ref)
