"""The declarative plan-based pipeline API (DESIGN.md §12).

Three PRs of growth left four overlapping entry points — one-shot
``substrat()``, the phase functions, the resumable ``SearchState`` engine
API, and the service scheduler — each with its own way of spelling "which
subset finder" and "which search engine".  This module collapses them onto
one declarative object executed by one engine:

    from repro.core.plan import plan, execute

    p = plan("gen_dst", cfg=GenDSTConfig(psi=20),
             sub_automl=AutoMLConfig(n_trials=12))
    result = execute(p, X, y, key=jax.random.key(0))

A ``Plan`` names a **SubsetStrategy** (registry: ``core/strategies.py`` —
Gen-DST, the island variant, every paper baseline, the ASP-style proxy
scorer, or any third-party registration) plus the subset shape, and a
**SearchBackend** (registry: ``automl/engine.py`` — ``batched``/``loop``/
third-party) plus the two AutoML pass budgets.  ``execute()`` is the one
driver: factorize → strategy → subset → sub-AutoML → restricted fine-tune.

``substrat()``, the service scheduler, and the examples are thin clients of
this API; ``plan_from_config`` converts the legacy ``SubStratConfig`` blob
(and the deprecated ``dst_fn=`` escape hatch) into an equivalent ``Plan``,
so old call sites produce identical results through the new path.

Plans are frozen and hashable (strategy options are stored as sorted
``(key, value)`` items): the service layer derives DST-cache keys directly
from ``(strategy, strategy_opts, n, m)``, which is what makes *every*
registered strategy cacheable and servable, not just Gen-DST.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, List, Optional, Tuple, Union

import jax
import numpy as np

from ..automl.engine import AutoMLConfig, automl_fit, get_backend
from ..obs import trace as _trace
from .gen_dst import GenDSTConfig, default_dst_size
from .measures import CodedDataset, factorize
from .strategies import SubsetResult, get_strategy, run_strategy

__all__ = ["Plan", "plan", "execute", "plan_from_config"]


def _norm_opts(opts) -> Tuple[Tuple[str, object], ...]:
    """Normalize strategy options to sorted hashable items."""
    items = sorted(dict(opts).items())
    return tuple((k, v) for k, v in items)


@dataclasses.dataclass(frozen=True)
class Plan:
    """A declarative description of one SubStrat run.

    ``strategy`` is a SubsetStrategy registry name (or a bare callable for
    un-registered generators — those bypass the service cache).
    ``backend``, when set, overrides the SearchBackend of *both* AutoML
    passes.  All other fields mirror the paper's three-step strategy
    (§1.1): subset shape ``n``/``m`` (None = paper defaults), the step-2
    budget ``sub_automl``, and the step-3 restricted pass ``ft_automl``
    (skipped entirely by ``fine_tune=False`` — SubStrat-NF)."""
    strategy: Union[str, Callable] = "gen_dst"
    strategy_opts: Tuple[Tuple[str, object], ...] = ()
    n: Optional[int] = None
    m: Optional[int] = None
    fine_tune: bool = True
    sub_automl: AutoMLConfig = AutoMLConfig()
    ft_automl: AutoMLConfig = AutoMLConfig(n_trials=6, rungs=(60,))
    backend: Optional[str] = None
    # opt into the scheduler's standing cross-rung megabatch (DESIGN.md §13).
    # Off, the job still merges, but only with cohorts at its exact
    # (rung_i, epochs) — the pre-§13 lockstep behavior.
    continuous_batching: bool = True
    # opt into portfolio warm-starts from the server's experience store
    # (DESIGN.md §17).  Off, the sub-AutoML pass always seeds its full cold
    # rung-0 population, regardless of accumulated history.
    warm_start: bool = True

    def __post_init__(self):
        if not callable(self.strategy):
            get_strategy(self.strategy)        # fail fast, listing names
        if self.backend is not None:
            get_backend(self.backend)
        object.__setattr__(self, "strategy_opts", _norm_opts(self.strategy_opts))

    def resolved_sub_automl(self) -> AutoMLConfig:
        if self.backend is not None:
            return dataclasses.replace(self.sub_automl, backend=self.backend)
        return self.sub_automl

    def resolved_ft_automl(self) -> AutoMLConfig:
        if self.backend is not None:
            return dataclasses.replace(self.ft_automl, backend=self.backend)
        return self.ft_automl

    @property
    def cacheable(self) -> bool:
        """Whether this plan's subset is DST-cache eligible: a *registered*
        strategy whose output is a pure function of (dataset, n, m, opts)."""
        return (not callable(self.strategy)
                and get_strategy(self.strategy).cacheable)

    @property
    def batchable(self) -> bool:
        """Whether the strategy can fuse same-shaped concurrent searches."""
        return (not callable(self.strategy)
                and get_strategy(self.strategy).batch_fn is not None)

    def subset_identity(self, coded: CodedDataset) -> tuple:
        """The hashable identity of this plan's subset-search problem on
        ``coded`` — the service cache-key payload: the resolved subset shape
        plus the strategy name and options."""
        N, M = coded.codes.shape
        dn, dm = default_dst_size(N, M)
        n = dn if self.n is None else min(self.n, N)
        m = dm if self.m is None else min(self.m, M)
        return (n, m, self.strategy, self.strategy_opts)


def plan(
    strategy: Union[str, Callable] = "gen_dst",
    *,
    n: Optional[int] = None,
    m: Optional[int] = None,
    fine_tune: bool = True,
    sub_automl: Optional[AutoMLConfig] = None,
    ft_automl: Optional[AutoMLConfig] = None,
    backend: Optional[str] = None,
    continuous_batching: bool = True,
    warm_start: bool = True,
    **strategy_opts,
) -> Plan:
    """Build a ``Plan``; extra keyword arguments become strategy options.

    ``plan("mc", budget=4000)`` configures the Monte-Carlo strategy;
    ``plan("gen_dst", cfg=GenDSTConfig(psi=40))`` the genetic search."""
    kw = {}
    if sub_automl is not None:
        kw["sub_automl"] = sub_automl
    if ft_automl is not None:
        kw["ft_automl"] = ft_automl
    return Plan(strategy=strategy, strategy_opts=_norm_opts(strategy_opts),
                n=n, m=m, fine_tune=fine_tune, backend=backend,
                continuous_batching=continuous_batching,
                warm_start=warm_start, **kw)


def plan_from_config(config, dst_fn: Optional[Callable] = None) -> Plan:
    """Convert a legacy ``SubStratConfig`` (+ optional ``dst_fn``) into the
    equivalent ``Plan`` — the compatibility bridge old call sites ride."""
    if dst_fn is not None:
        strategy, opts = dst_fn, ()
    else:
        strategy = "gen_dst"
        opts = (("cfg", config.resolved_gen()),)
    return Plan(
        strategy=strategy, strategy_opts=opts,
        n=config.n, m=config.m, fine_tune=config.fine_tune,
        sub_automl=config.resolved_sub_automl(),
        ft_automl=config.resolved_ft_automl(),
    )


def execute(
    p: Plan,
    X: np.ndarray,
    y: np.ndarray,
    *,
    key: Optional[jax.Array] = None,
    coded: Optional[CodedDataset] = None,
    X_test: Optional[np.ndarray] = None,
    y_test: Optional[np.ndarray] = None,
    trace_sink: Optional[List[dict]] = None,
):
    """Run one plan end to end; returns a ``SubStratResult``.

    The single driver behind ``substrat()`` and the scheduler's phase
    machine: factorize once, run the plan's subset strategy, train the
    sub-AutoML pass on the subset, then the restricted fine-tune on the
    full data (or the SubStrat-NF test evaluation when ``fine_tune`` is
    off).

    The per-phase ``times`` ledger is recorded as spans (DESIGN.md §15.1):
    pass ``trace_sink=[]`` to receive the closed span records — the same
    shape the serving tier emits — for ``obs.trace.render_timeline``; the
    result's ``times`` keys are unchanged either way."""
    from .substrat import (
        SubStratResult, build_subset, dst_feature_columns, nf_test_eval,
    )
    key = jax.random.key(0) if key is None else key
    times = {}
    spans = [] if trace_sink is None else trace_sink
    strat_name = (p.strategy if isinstance(p.strategy, str)
                  else getattr(p.strategy, "__name__", "<callable>"))
    tid = _trace.span_id("substrat-oneshot", strat_name)

    @contextlib.contextmanager
    def _phase(name, tkey):
        t0 = time.perf_counter()
        with _trace.span(spans, tid, name, phase=name):
            yield
        times[tkey] = times.get(tkey, 0.0) + (time.perf_counter() - t0)

    with _phase("factorize", "factorize_s"):
        if coded is None:
            coded = factorize(X, y)

    with _phase("gen_dst", "gen_dst_s"):
        subset: SubsetResult = run_strategy(
            p.strategy, key, coded, p.n, p.m, p.strategy_opts)
    col_idx = dst_feature_columns(subset.col_mask, coded.target_col)

    with _phase("sub_automl", "automl_sub_s"):
        X_sub, y_sub = build_subset(X, y, subset.row_idx, col_idx, key)
        intermediate = automl_fit(X_sub, y_sub, config=p.resolved_sub_automl())

    if p.fine_tune:
        with _phase("fine_tune", "fine_tune_s"):
            final = automl_fit(
                X, y,
                config=p.resolved_ft_automl(),
                restrict_family=intermediate.spec.family,
                X_test=X_test, y_test=y_test,
            )
    else:
        final = intermediate
        if X_test is not None:
            final = nf_test_eval(intermediate, y_sub, col_idx, X_test, y_test)

    return SubStratResult(
        final=final,
        intermediate=intermediate,
        row_idx=subset.row_idx,
        col_idx=col_idx,
        dst_fitness=subset.fitness,
        times=times,
        total_time_s=sum(times.values()),
        strategy=subset.strategy,
    )
