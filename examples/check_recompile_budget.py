"""Recompile-budget gate: steady-state serving must not re-trace
(DESIGN.md §15.4).

    PYTHONPATH=src python examples/check_recompile_budget.py
        [--rounds 2] [--jobs 2] [--scale 0.1] [--trials 4]

Round 0 is the warmup: it pays every jit tracing (Gen-DST evolve kernel,
the fused rung evaluator, the promotion mask, full-column entropy).  The
script then snapshots ``obs.jaxprof.tracing_snapshot()`` and replays
``--rounds`` more rounds of *same-shaped* traffic — same datasets, same
plan, fresh PRNG keys, so trial hyperparameters (traced scalars) differ
while every array shape is identical.  PR 6's claim is that shapes, not
values, drive compilation; therefore the steady state must add **zero**
new tracings.  Any nonzero delta prints the offending call sites and
exits 1 — that is a recompile leaked into the serving path.

Plans run with ``fine_tune=False``: the restricted fine-tune pass trains
on the *full* dataset only after a winner family is known, so its first
occurrence may legitimately land in a post-warmup round.  The steady-state
budget is about the per-rung serving path, which SubStrat-NF exercises
fully.  CI runs this as the recompile-budget step.

The same gate also covers the Gen-DST backends directly (DESIGN.md §16):
for every ``GEN_DST_BACKENDS`` entry, one warmup ``gen_dst`` call pays the
tracing, then two same-shaped calls with fresh keys must add zero — the
backend switch is a *static* jit argument, so switching backends between
runs recompiles, but re-running one backend never does.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

import numpy as np  # noqa: E402

from repro.automl.engine import AutoMLConfig  # noqa: E402
from repro.core.gen_dst import GEN_DST_BACKENDS, GenDSTConfig, gen_dst  # noqa: E402
from repro.core.measures import factorize  # noqa: E402
from repro.core.plan import plan  # noqa: E402
from repro.data.tabular import PAPER_DATASETS, make_dataset, train_test_split  # noqa: E402
from repro.obs import jaxprof  # noqa: E402
from repro.service import SubStratServer  # noqa: E402


def run_round(srv, datasets, p, n_jobs, key0):
    ids = []
    for i in range(n_jobs):
        name, Xtr, ytr, Xte, yte = datasets[i % len(datasets)]
        ids.append(srv.submit(Xtr, ytr, tenant="acme",
                              key=jax.random.key(key0 + i), plan=p,
                              X_test=Xte, y_test=yte))
    srv.run()
    for jid in ids:
        st = srv.poll(jid)
        assert st.phase == "done", f"job {jid} ended in {st.phase}"
    return ids


def check_gen_dst_backends(rounds: int) -> int:
    """Warmup + ``rounds`` same-shaped ``gen_dst`` calls per backend: the
    steady state must add 0 jit tracings on every backend, including the
    Pallas legs (interpret mode on CPU — tracing hygiene is backend-blind).
    Returns the number of failing backends."""
    rng = np.random.default_rng(0)
    X = np.column_stack([rng.integers(0, k, 2_000)
                         for k in (3, 5, 17, 2, 40)]).astype(float)
    y = rng.integers(0, 2, 2_000).astype(float)
    coded = factorize(X, y)
    failures = 0
    for backend in GEN_DST_BACKENDS:
        cfg = GenDSTConfig(psi=4, phi=8, cross_every=2, backend=backend)
        res = gen_dst(jax.random.key(0), coded, 20, 3, cfg)   # warmup
        jax.block_until_ready(res.fitness)
        warm = jaxprof.tracing_snapshot()
        for r in range(rounds):
            res = gen_dst(jax.random.key(1 + r), coded, 20, 3, cfg)
            jax.block_until_ready(res.fitness)
        delta = jaxprof.new_tracings_since(warm)
        if delta:
            failures += 1
            print(f"FAIL: gen_dst backend={backend} re-traced after warmup:")
            for site, n in sorted(delta.items()):
                print(f"  {site}: +{int(n)}")
        else:
            print(f"gen_dst backend={backend}: 0 new tracings "
                  f"({rounds} same-shaped rounds, fresh keys)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2,
                    help="steady-state rounds replayed after the warmup")
    ap.add_argument("--jobs", type=int, default=2,
                    help="jobs per round (constant so megabatch group "
                         "sizes match between warmup and steady state)")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--trials", type=int, default=4)
    args = ap.parse_args()

    datasets = []
    for name in ("D3", "D6")[:max(1, min(2, args.jobs))]:
        X, y = make_dataset(PAPER_DATASETS[name], scale=args.scale)
        Xtr, ytr, Xte, yte = train_test_split(X, y)
        datasets.append((name, Xtr, ytr, Xte, yte))

    p = plan("gen_dst", cfg=GenDSTConfig(psi=8, phi=20), fine_tune=False,
             sub_automl=AutoMLConfig(n_trials=args.trials, rungs=(30, 80)))

    srv = SubStratServer()
    run_round(srv, datasets, p, args.jobs, key0=0)
    warm = jaxprof.tracing_snapshot()
    print(f"warmup: {int(sum(warm.values()))} jit tracings across "
          f"{len(warm)} call sites")
    for site, n in sorted(warm.items()):
        print(f"  {site}: {int(n)}")

    for r in range(args.rounds):
        run_round(srv, datasets, p, args.jobs, key0=100 * (r + 1))
        delta = jaxprof.new_tracings_since(warm)
        if delta:
            print(f"FAIL: round {r + 1} re-traced after warmup:")
            for site, n in sorted(delta.items()):
                print(f"  {site}: +{int(n)}")
            return 1
        print(f"round {r + 1}: 0 new tracings "
              f"({args.jobs} jobs, fresh keys, same shapes)")

    if check_gen_dst_backends(args.rounds):
        return 1

    print("recompile budget: PASS (steady state adds 0 jit tracings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
