"""Quickstart: SubStrat vs Full-AutoML on a paper-shaped tabular dataset.

    PYTHONPATH=src python examples/quickstart.py [--scale 0.5] [--trials 10]
                                                 [--backend batched|loop]
                                                 [--strategy gen_dst|mc|...]

Reproduces the paper's headline comparison on one dataset: run the AutoML
engine on the full data, then execute a SubStrat ``Plan`` (subset strategy
-> AutoML -> restricted fine-tune) and report time-reduction + relative
accuracy.  ``--scale 0.1 --trials 4`` is the CI smoke configuration;
``--backend loop`` pins the sequential AutoML reference engine (DESIGN.md
§10.3); ``--strategy`` swaps the subset finder across the SubsetStrategy
registry (DESIGN.md §12.1) — the paper's Gen-DST by default.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.automl.engine import AutoMLConfig, automl_fit  # noqa: E402
from repro.core.gen_dst import GenDSTConfig  # noqa: E402
from repro.core.plan import execute, plan  # noqa: E402
from repro.core.strategies import available_strategies  # noqa: E402
from repro.data.tabular import PAPER_DATASETS, make_dataset, train_test_split  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.5,
                    help="dataset row-count scale (0.1 = smoke size)")
    ap.add_argument("--trials", type=int, default=10,
                    help="AutoML trial budget for the full and sub passes")
    ap.add_argument("--backend", default="batched", choices=("batched", "loop"),
                    help="AutoML engine backend (DESIGN.md §10.3)")
    ap.add_argument("--strategy", default="gen_dst",
                    choices=available_strategies(),
                    help="SubsetStrategy registry entry (DESIGN.md §12.1)")
    args = ap.parse_args()

    spec = PAPER_DATASETS["D3"]           # car insurance, 10k x 18
    X, y = make_dataset(spec, scale=args.scale)
    Xtr, ytr, Xte, yte = train_test_split(X, y)
    print(f"dataset {spec.name} ({spec.domain}): {Xtr.shape[0]} train rows, "
          f"{Xtr.shape[1]} columns, engine backend {args.backend}, "
          f"subset strategy {args.strategy}")

    automl_cfg = AutoMLConfig(n_trials=args.trials, rungs=(60, 200),
                              backend=args.backend)
    t0 = time.perf_counter()
    full = automl_fit(Xtr, ytr, config=automl_cfg, X_test=Xte, y_test=yte)
    t_full = time.perf_counter() - t0
    print(f"\nFull-AutoML : {t_full:6.1f}s  test-acc {full.test_acc:.3f} "
          f"({full.spec.family}, {full.n_trials} trials)")

    opts = {"cfg": GenDSTConfig(psi=10, phi=24)} \
        if args.strategy in ("gen_dst", "gen_dst_islands") else {}
    p = plan(
        args.strategy,
        sub_automl=automl_cfg,
        ft_automl=AutoMLConfig(n_trials=4, rungs=(120,), backend=args.backend),
        **opts,
    )
    res = execute(p, Xtr, ytr, key=jax.random.key(0), X_test=Xte, y_test=yte)
    print(f"SubStrat    : {res.total_time_s:6.1f}s  test-acc "
          f"{res.final.test_acc:.3f} ({res.final.spec.family})")
    print(f"  subset: {len(res.row_idx)} rows x {len(res.col_idx)}(+target) cols, "
          f"|H(d)-H(D)| = {-res.dst_fitness:.4f}")
    print(f"  phases: {', '.join(f'{k}={v:.1f}s' for k, v in res.times.items())}")
    print(f"\ntime-reduction     = {1 - res.total_time_s / t_full:+.1%}")
    print(f"relative-accuracy  = {res.final.test_acc / full.test_acc:.1%}")


if __name__ == "__main__":
    main()
