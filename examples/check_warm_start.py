"""CI gate for cross-tenant portfolio warm-starts (DESIGN.md §17).

    PYTHONPATH=src python examples/check_warm_start.py

Serves N history jobs cold on one scheduler, snapshots it, restores a
fresh scheduler from the snapshot (server restart), and asserts:

1. the experience store survives the snapshot bit-identically (wire-bytes
   equal) and the restored store yields byte-for-byte the same portfolio
   decision as the live one;
2. the restarted, warm-started server reaches the cold baseline's winner
   accuracy on every new job in *strictly fewer* dispatched sub-AutoML
   trials;
3. ``/v1/metrics`` (``SubStratServer.metrics_text()``) reports nonzero
   ``portfolio_hits_total`` and ``portfolio_trials_saved_total``.

Everything is seeded; a failure is a real regression, not flake.
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.automl.engine import AutoMLConfig  # noqa: E402
from repro.core.plan import plan  # noqa: E402
from repro.meta import portfolio_for  # noqa: E402
from repro.service import SubStratServer, wire  # noqa: E402
from repro.service.scheduler import Scheduler  # noqa: E402


def make_data(seed: int, N: int = 400, d: int = 8):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, N)
    X = np.column_stack([y * 1.5 + rng.normal(0, 0.8, N) for _ in range(d)])
    return X, y


def serve(scheduler: Scheduler, datasets, p):
    ids = [scheduler.submit(X, y, plan=p) for X, y in datasets]
    scheduler.run()
    results = []
    for jid in ids:
        job = scheduler.jobs[jid]
        assert job.phase == "done", f"job {jid} failed: {job.error!r}"
        results.append(job.result)
    return results


def main() -> None:
    automl = AutoMLConfig(n_trials=10, rungs=(8, 16))
    cold_plan = plan("mc", budget=200, fine_tune=False, sub_automl=automl,
                     warm_start=False)
    warm_plan = plan("mc", budget=200, fine_tune=False, sub_automl=automl)
    history = [make_data(300 + i) for i in range(4)]
    evals = [make_data(400 + i) for i in range(4)]

    # -- history phase, then a server restart from the snapshot ------------
    hist = Scheduler(warm_min_history=len(history) + 1)
    serve(hist, history, warm_plan)
    blob = hist.snapshot()
    restored = Scheduler()
    restored.load_snapshot(blob)

    live_bytes = wire.dumps(hist.experience.state_dict())
    rest_bytes = wire.dumps(restored.experience.state_dict())
    assert live_bytes == rest_bytes, \
        "experience store changed across snapshot/restore"
    qX, qy = evals[0]
    from repro.core.measures import factorize
    from repro.meta import meta_features
    feats = meta_features(factorize(qX, qy))
    for store in (hist.experience, restored.experience):
        assert store.n_trained() == len(history), store.n_trained()
    p_live = portfolio_for(hist.experience, feats, k=6, knn=4)
    p_rest = portfolio_for(restored.experience, feats, k=6, knn=4)
    assert p_live == p_rest, "portfolio decision changed across restore"
    print(f"snapshot round-trip OK: {len(history)} trained fingerprints, "
          f"portfolio of {len(p_live)} specs identical")

    # -- cold baseline on fresh datasets -----------------------------------
    cold = serve(Scheduler(), evals, cold_plan)
    cold_accs = [float(r.intermediate.val_acc) for r in cold]
    cold_trials = [r.intermediate.n_trials for r in cold]

    # -- warm serving on the restarted scheduler ---------------------------
    warm_server = SubStratServer(scheduler=restored)
    ids = [warm_server.submit(X, y, plan=warm_plan) for X, y in evals]
    warm = [warm_server.result(jid) for jid in ids]
    warm_trials = [r.intermediate.n_trials for r in warm]
    for i, (r, target) in enumerate(zip(warm, cold_accs)):
        acc = float(r.intermediate.val_acc)
        assert acc >= target - 1e-6, \
            f"warm job {i}: {acc} < cold winner {target}"
    assert sum(warm_trials) < sum(cold_trials), \
        f"warm dispatched {sum(warm_trials)} trials, cold " \
        f"{sum(cold_trials)} — no savings"
    print(f"warm run OK: reached all {len(evals)} cold winner accuracies "
          f"in {sum(warm_trials)} trials vs cold {sum(cold_trials)}")

    # -- the metrics surface saw it ----------------------------------------
    text = warm_server.metrics_text()

    def metric_value(name: str) -> float:
        total = 0.0
        for line in text.splitlines():
            if line.startswith(name) and " " in line:
                total += float(line.rsplit(" ", 1)[1])
        return total

    hits = metric_value("portfolio_hits_total")
    saved = metric_value("portfolio_trials_saved_total")
    assert hits == len(evals), f"portfolio_hits_total {hits} != {len(evals)}"
    assert saved > 0, "portfolio_trials_saved_total is zero"
    print(f"metrics OK: portfolio_hits_total={hits:.0f}, "
          f"portfolio_trials_saved_total={saved:.0f}")
    print("warm-start gate PASS")


if __name__ == "__main__":
    main()
