"""Batched serving example: prefill + token-by-token decode with a KV cache
(or SSM state), on any assigned architecture's reduced config.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --gen 32
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--gen", str(args.gen),
                "--temperature", "0.8"])


if __name__ == "__main__":
    main()
