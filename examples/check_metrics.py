"""Metrics smoke gate: scrape ``GET /v1/metrics`` during a real serve run
and validate the exposition (DESIGN.md §15.3).

    PYTHONPATH=src python examples/check_metrics.py [--jobs 2] [--scale 0.1]
                                                    [--trials 4]

Stands up the HTTP front end over an in-process scheduler, submits a pair
of jobs (the second is a DST-cache repeat of the first), waits for both
over ``/v1/result``, then scrapes ``/v1/metrics`` and fails (exit 1) unless

- every non-comment line parses as a Prometheus 0.0.4 sample,
- every sample's family carries ``# TYPE``/``# HELP`` headers,
- the dispatch counters are nonzero (``dispatches_total`` summed over its
  ``mode`` children >= 1, and ``dispatch_latency_seconds_count`` agrees),
- the DST cache saw the repeat (``cache_hits_total >= 1``), and
- jit-tracing accounting is live (``jax_jit_tracings_total`` > 0 — a cold
  process must have compiled *something* to finish a job).

CI runs this as the metrics-smoke step.
"""
import argparse
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.automl.engine import AutoMLConfig  # noqa: E402
from repro.core.gen_dst import GenDSTConfig  # noqa: E402
from repro.core.plan import plan  # noqa: E402
from repro.data.tabular import PAPER_DATASETS, make_dataset, train_test_split  # noqa: E402
from repro.service import (  # noqa: E402
    SubStratHTTPClient, SubStratHTTPServer, SubStratServer,
)

# sample line: name{label="v",...} value  — value may be int/float/+Inf
_SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})?'
    r' (-?(?:[0-9]*\.?[0-9]+(?:[eE][+-]?[0-9]+)?|\+Inf|-Inf|NaN))$')


def parse_exposition(text: str):
    """Validate the text format; returns {family: summed value} and the
    set of families that carried TYPE headers.  Raises ValueError with the
    offending line on any malformed input."""
    typed, helped, sums = set(), set(), {}
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            typed.add(parts[2])
            continue
        if line.startswith("# HELP "):
            if len(line.split(" ", 3)) < 3:
                raise ValueError(f"line {lineno}: malformed HELP: {line!r}")
            helped.add(line.split(" ", 3)[2])
            continue
        if line.startswith("#"):
            continue   # free-form comment — legal
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        name, value = m.group(1), m.group(3)
        # histogram series sample under the family's TYPE header
        family = re.sub(r"_(bucket|sum|count)$", "", name)
        if name not in typed and family not in typed:
            raise ValueError(
                f"line {lineno}: sample {name!r} precedes its TYPE header")
        if value not in ("+Inf", "-Inf", "NaN"):
            sums[name] = sums.get(name, 0.0) + float(value)
    return sums, typed


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--trials", type=int, default=4)
    args = ap.parse_args()

    X, y = make_dataset(PAPER_DATASETS["D3"], scale=args.scale)
    Xtr, ytr, Xte, yte = train_test_split(X, y)
    p = plan("gen_dst", cfg=GenDSTConfig(psi=8, phi=20),
             sub_automl=AutoMLConfig(n_trials=args.trials, rungs=(30, 80)),
             ft_automl=AutoMLConfig(n_trials=4, rungs=(80,)))

    http = SubStratHTTPServer(SubStratServer()).start()
    failures = []
    try:
        client = SubStratHTTPClient(http.url)
        ids = [client.submit(Xtr, ytr, tenant="acme", key=jax.random.key(i),
                             plan=p, X_test=Xte, y_test=yte)
               for i in range(args.jobs)]
        for jid in ids:
            client.result(jid)

        text = client.metrics()
        print(f"scraped {len(text.splitlines())} exposition lines "
              f"from {http.url}/v1/metrics")
        try:
            sums, typed = parse_exposition(text)
        except ValueError as e:
            print(f"FAIL: {e}")
            return 1

        def check(cond, what):
            print(("ok:   " if cond else "FAIL: ") + what)
            if not cond:
                failures.append(what)

        dispatches = sum(v for n, v in sums.items()
                         if n == "dispatches_total")
        check(dispatches >= 1,
              f"dispatches_total summed over modes >= 1 (got {dispatches})")
        check(sums.get("dispatch_latency_seconds_count", 0.0) == dispatches,
              "dispatch_latency_seconds_count agrees with dispatches_total")
        check(sums.get("cache_hits_total", 0.0) >= 1,
              "cache_hits_total >= 1 (job 1 repeats job 0's dataset)")
        check(sums.get("jobs_finished_total", 0.0) == len(ids),
              f"jobs_finished_total == {len(ids)}")
        check("jax_jit_tracings_total" in typed
              and sums.get("jax_jit_tracings_total", 0.0) > 0,
              "jax_jit_tracings_total present and nonzero")
    finally:
        http.close()
        if hasattr(http.server.scheduler, "close"):
            http.server.scheduler.close()

    print(f"metrics smoke: {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
