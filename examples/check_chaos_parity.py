"""Diff two ``serve_tabular.py --json`` artifacts for result parity.

    python examples/check_chaos_parity.py BASELINE.json CHAOS.json

Used by the CI chaos gate: a run with ``--workers 2 --kill-worker 0`` must
produce the same winner family/preproc and the same trial accuracies
(within 1e-6) as the fault-free in-process run — crash recovery may cost
time, never answers.  When the second artifact ran on the cross-process
tier, also asserts its transport stats actually saw the injected failure
(so the gate can't silently pass because the kill never fired).
"""
import json
import sys


def main(baseline_path: str, chaos_path: str) -> None:
    base = json.load(open(baseline_path))
    chaos = json.load(open(chaos_path))
    a, b = base["jobs"], chaos["jobs"]
    assert len(a) == len(b), f"job count differs: {len(a)} vs {len(b)}"
    for ja, jb in zip(a, b):
        ctx = f"job {ja['job']} ({ja['dataset']})"
        assert ja["family"] == jb["family"], \
            f"{ctx}: family {ja['family']} vs {jb['family']}"
        assert ja["preproc"] == jb["preproc"], \
            f"{ctx}: preproc {ja['preproc']} vs {jb['preproc']}"
        assert abs(ja["test_acc"] - jb["test_acc"]) <= 1e-6, \
            f"{ctx}: test_acc {ja['test_acc']} vs {jb['test_acc']}"
        for kind in ("trials", "sub_trials"):
            assert len(ja[kind]) == len(jb[kind]), f"{ctx}: {kind} length"
            for x, y in zip(ja[kind], jb[kind]):
                assert abs(x - y) <= 1e-6, f"{ctx}: {kind} {x} vs {y}"
    tr = chaos.get("transport")
    if tr is not None and tr["workers_total"] > tr["workers_alive"]:
        assert tr["worker_failures"] >= 1, tr
        assert tr["redispatched_tasks"] >= 1, tr
        print(f"transport saw {tr['worker_failures']} worker failure(s), "
              f"{tr['redispatched_tasks']} re-dispatched task(s)")
    print(f"chaos parity OK: {len(a)} jobs identical within 1e-6")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2])
