"""Baseline comparison driver (paper Table 4, one dataset): SubStrat vs the
baseline DST generators vs Full-AutoML.

    PYTHONPATH=src python examples/automl_tabular.py --dataset D6 --scale 0.2 \
        [--backend batched|loop]

``--backend`` switches every AutoML pass (full, sub, fine-tune) between the
batched vmap engine and the sequential reference (DESIGN.md §10.3).
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import QUICK_AUTOML, run_dataset, substrat_config  # noqa: E402
from repro.data.tabular import PAPER_DATASETS  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="D6", choices=sorted(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--methods", nargs="*", default=None)
    ap.add_argument("--backend", default="batched", choices=("batched", "loop"))
    args = ap.parse_args()

    full, results = run_dataset(
        PAPER_DATASETS[args.dataset], scale=args.scale, methods=args.methods,
        full_cfg=dataclasses.replace(QUICK_AUTOML, backend=args.backend),
        sub_cfg=substrat_config(automl_backend=args.backend),
    )
    print(f"\n{args.dataset}: Full-AutoML {full.time_s:.1f}s, "
          f"test-acc {full.test_acc:.3f}\n")
    print(f"{'method':14s} {'time':>8s} {'time-red':>9s} {'acc':>6s} {'rel-acc':>8s}")
    for r in sorted(results, key=lambda r: -r.relative_accuracy):
        print(f"{r.method:14s} {r.time_s:7.1f}s {r.time_reduction:+8.1%} "
              f"{r.test_acc:6.3f} {r.relative_accuracy:7.1%}")


if __name__ == "__main__":
    main()
