"""Serving walkthrough: the multi-tenant SubStrat job server.

    PYTHONPATH=src python examples/serve_tabular.py [--jobs 4] [--scale 0.3]
                                                    [--trials 8] [--workers 2]
                                                    [--kill-worker 0]
                                                    [--json out.json]

Submits ``--jobs`` AutoML jobs in same-dataset pairs over two tabular
datasets — so every odd job is a repeat submission — from two tenants,
drives the scheduler,
and prints what the service layer did for each job: which phases ran, which
were skipped by the DST cache (``gen_dst`` becomes a lookup) or warm-start
(the sub-AutoML pass is skipped when the winner family is already known),
and how rung cohorts from concurrent jobs merged into shared batched
dispatches.  Ends with the per-tenant accounting and a budget-rejection
demo.  ``--jobs 2 --scale 0.1 --trials 4`` is the CI smoke configuration
(job 1 is a cache-hit repeat of job 0).

With ``--workers N`` the same jobs run on the cross-process serving tier
instead: rung evaluations ship over the versioned wire format to ``N``
worker subprocesses (DESIGN.md §14).  ``--kill-worker W [--kill-task T]``
injects a deterministic crash — worker ``W`` exits hard when it dequeues
its ``T``-th task — and the front end detects the loss, re-dispatches the
orphaned cohorts to the survivors, and still produces the fault-free
answer.  ``--json PATH`` writes per-job results (winner family, test
accuracy, trial accuracies) so a chaos run can be diffed against a
fault-free run; the CI chaos gate does exactly that.

Every run ends with the observability surface (DESIGN.md §15): one job's
span timeline (on chaos runs, the job whose killed task re-dispatched —
the retry shows as a distinct ``(retry #1)`` span with its own queue-wait
and eval children) and the full Prometheus exposition that ``GET
/v1/metrics`` serves, including ``heartbeat_misses_total`` after a kill.
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.automl.engine import AutoMLConfig  # noqa: E402
from repro.core.gen_dst import GenDSTConfig  # noqa: E402
from repro.core.plan import plan  # noqa: E402
from repro.data.tabular import PAPER_DATASETS, make_dataset, train_test_split  # noqa: E402
from repro.obs.trace import render_timeline  # noqa: E402
from repro.service import (  # noqa: E402
    BudgetExceeded, DistributedScheduler, ProcessWorkerPool, SubStratServer,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=4,
                    help="submissions, paired over 2 datasets (odd jobs are "
                         "repeats of the preceding even job's dataset)")
    ap.add_argument("--scale", type=float, default=0.3,
                    help="dataset row-count scale (0.1 = smoke size)")
    ap.add_argument("--trials", type=int, default=8,
                    help="AutoML trial budget of the sub pass")
    ap.add_argument("--workers", type=int, default=0,
                    help="run rung evaluation on N worker subprocesses "
                         "(0 = in-process scheduler, the default)")
    ap.add_argument("--kill-worker", type=int, default=None, metavar="W",
                    help="chaos: worker W exits hard when it dequeues its "
                         "--kill-task'th task (requires --workers)")
    ap.add_argument("--kill-task", type=int, default=0, metavar="T",
                    help="which dequeue of worker W triggers the kill "
                         "(default 0 = its first task)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-job results (family, test acc, trial "
                         "accuracies) + transport stats as JSON for parity "
                         "diffs between chaos and fault-free runs")
    args = ap.parse_args()
    if args.kill_worker is not None and args.workers <= 0:
        ap.error("--kill-worker requires --workers >= 1")

    datasets = []
    for name in ("D3", "D6"):
        X, y = make_dataset(PAPER_DATASETS[name], scale=args.scale)
        Xtr, ytr, Xte, yte = train_test_split(X, y)
        datasets.append((name, Xtr, ytr, Xte, yte))

    p = plan(
        "gen_dst", cfg=GenDSTConfig(psi=8, phi=20),
        sub_automl=AutoMLConfig(n_trials=args.trials, rungs=(30, 80)),
        ft_automl=AutoMLConfig(n_trials=4, rungs=(80,)),
    )

    if args.workers > 0:
        # fault events are primitive tuples (worker, task, action, seconds) —
        # the same shape tests/harness/faultsim.py compiles FaultPlans to
        events = ()
        if args.kill_worker is not None:
            events = ((args.kill_worker, args.kill_task, "kill", 0.0),)
            print(f"chaos: worker {args.kill_worker} will exit at its "
                  f"task #{args.kill_task}")
        print(f"starting {args.workers} worker subprocess(es)...", flush=True)
        pool = ProcessWorkerPool(args.workers, fault_events=events)
        srv = SubStratServer(
            scheduler=DistributedScheduler(pool, stall_timeout_s=120.0))
    else:
        srv = SubStratServer()

    ids = []
    for i in range(args.jobs):
        name, Xtr, ytr, Xte, yte = datasets[(i // 2) % len(datasets)]
        jid = srv.submit(Xtr, ytr, tenant=("acme" if i % 2 == 0 else "globex"),
                         key=jax.random.key(i), plan=p,
                         X_test=Xte, y_test=yte)
        ids.append((jid, name))
        print(f"submitted job {jid} ({name}, tenant "
              f"{'acme' if i % 2 == 0 else 'globex'})")

    try:
        srv.run()

        print("\njob  dataset  phase  dst      sub-automl  result")
        records = []
        for jid, name in ids:
            st = srv.poll(jid)
            res = srv.result(jid)
            dst = ("cache-hit" if st.cache_hit else
                   f"{st.times['gen_dst_s']:.2f}s")
            sub = ("warm-start" if st.warm_started else
                   f"{st.times.get('automl_sub_s', 0.0):.2f}s")
            print(f"{jid:>3}  {name:>7}  {st.phase:>5}  {dst:>8}  {sub:>10}  "
                  f"{res.final.spec.family}, test-acc "
                  f"{res.final.test_acc:.3f}, {res.total_time_s:.2f}s")
            records.append({
                "job": jid, "dataset": name,
                "family": res.final.spec.family,
                "preproc": res.final.spec.preproc,
                "test_acc": float(res.final.test_acc),
                "trials": [float(v) for _, v in res.final.trials],
                "sub_trials": [float(v) for _, v in res.intermediate.trials],
            })

        stats = srv.stats()
        print(f"\ncache: {stats['cache']['hits']} hits / "
              f"{stats['cache']['misses']} misses, {stats['cache']['size']} DSTs")
        print(f"rung dispatches: {stats['merged_rungs']} merged "
              f"(covering {stats['merged_jobs']} job-rungs, "
              f"{stats['hetero_rungs']} shape-padded), "
              f"{stats['solo_rungs']} solo")
        if "transport" in stats:
            tr = stats["transport"]
            print(f"transport: {tr['remote_tasks']} remote tasks, "
                  f"{tr['worker_failures']} worker failures, "
                  f"{tr['redispatched_tasks']} re-dispatched, "
                  f"{tr['workers_alive']}/{tr['workers_total']} workers alive")
        for tenant, acc in stats["tenants"].items():
            print(f"tenant {tenant}: {acc['jobs_submitted']} jobs, "
                  f"{acc['spent_s']:.2f}s compute")

        # trace timeline: prefer a job with a visible retry span (chaos runs)
        # so the killed task's re-dispatch is what gets shown
        tl_jid = ids[0][0]
        for jid, _ in ids:
            tr = srv.trace(jid)
            if tr and any(s.get("attempt", 0) > 0 for s in tr["spans"]):
                tl_jid = jid
                break
        tr = srv.trace(tl_jid)
        print(f"\ntrace timeline (job {tl_jid}, trace {tr['trace_id']}):")
        print(render_timeline(tr["spans"]))

        print("\n/v1/metrics exposition:")
        print(srv.metrics_text())

        if args.json:
            payload = {"jobs": records,
                       "transport": stats.get("transport")}
            Path(args.json).write_text(json.dumps(payload, indent=2))
            print(f"wrote {args.json}")
    finally:
        if hasattr(srv.scheduler, "close"):
            srv.scheduler.close()

    # budget accounting: a tenant over its budget is refused at submit
    srv.set_budget("acme", 1e-6)
    _, Xtr, ytr, *_ = datasets[0]
    try:
        srv.submit(Xtr, ytr, tenant="acme", plan=p)
    except BudgetExceeded as e:
        print(f"\nbudget rejection works: {e}")


if __name__ == "__main__":
    main()
