"""End-to-end LM training driver with SubStrat corpus selection.

    PYTHONPATH=src python examples/train_lm.py                  # CPU-sized
    PYTHONPATH=src python examples/train_lm.py --preset full    # ~130M mamba2

Trains the mamba2-130m architecture (reduced on CPU) for a few hundred
steps, comparing a run on the full synthetic corpus against a run on a
Gen-DST entropy-preserving subset (SubStrat step 1 at LM scale), with
checkpoint/restart enabled.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["cpu-small", "full"], default="cpu-small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="mamba2-130m")
    args = ap.parse_args()

    common = ["--arch", args.arch, "--preset", args.preset,
              "--steps", str(args.steps), "--batch", "8", "--seq", "128"]

    print("=== run A: full corpus ===")
    train_main(common + ["--ckpt-dir", "checkpoints/full"])

    print("\n=== run B: SubStrat-selected corpus subset (step 1 of the paper "
          "strategy at LM scale) ===")
    train_main(common + ["--substrat-subset", "256",
                         "--ckpt-dir", "checkpoints/substrat"])


if __name__ == "__main__":
    main()
